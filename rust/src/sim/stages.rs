//! The five composable stages the serving engine drives over the event
//! kernel.
//!
//! [`crate::coordinator::engine::Engine::run`] used to be a ~300-line
//! monolith interleaving arrival generation, admission, dispatch,
//! virtual-time advance, monitor/regime logic, op execution, and
//! accounting. Each concern now lives in its own stage with its own
//! private state; the engine is a thin driver that wires them together
//! and broadcasts [`super::event::Event`]s to observers:
//!
//! * [`ArrivalSource`] — pre-generates every request (stream-split PRNG,
//!   exactly the legacy sequence) and seeds the [`EventQueue`].
//! * [`AdmissionStage`] — wraps
//!   [`crate::coordinator::scheduler::AdmissionCtrl`]; turns an admitted
//!   arrival into an [`Active`] execution record.
//! * [`DispatchStage`] — wraps the
//!   [`crate::coordinator::scheduler::Scheduler`] policy and owns the
//!   candidate construction, caching per-request placement/remaining-work
//!   lookups between picks (the legacy loop rebuilt them from scratch on
//!   every iteration).
//! * [`ExecStage`] — op execution on the device, energy/latency
//!   accounting, placement-override feasibility, completion.
//! * [`MonitorStage`] — periodic monitor sampling, regime-change
//!   re-planning, latency-profile refresh, and the drift fast path.
//!
//! **Replay contract.** For a fixed seed the stages reproduce the legacy
//! monolith bit for bit (`rust/tests/golden_determinism.rs`): arrival
//! order (including NaN and equal-time ties), every virtual-time advance,
//! the dispatch-time-aligned monitor check, and the exact float
//! expressions for candidate start times, slack, and backlog estimates
//! were all preserved deliberately. Monitor ticks are due at
//! `last_sample + period` but *delivered* at the first dispatch whose
//! advance reaches the due time, because the device clock is piecewise —
//! it only materializes at dispatch points (sampling mid-idle would read
//! snapshots the legacy engine never took).

use anyhow::{bail, Result};

use crate::config::schema::{PolicyKind, SchedulerKind};
use crate::coordinator::engine::{NumericsHook, PlannerInfo};
use crate::coordinator::plan_cache::PlanCache;
use crate::coordinator::repartition::RepartitionController;
use crate::coordinator::request::{Request, RequestOutcome, StreamSpec};
use crate::coordinator::scheduler::{
    by_kind, remaining_backlog_at, AdmissionCounters, AdmissionCtrl, AdmissionPolicy, Candidate,
    Scheduler,
};
use crate::graph::ModelGraph;
use crate::metrics::{EnergyAccount, LatencyRecorder};
use crate::partition::plan::{per_op_latencies, Plan, INPUT_CPU_FRAC};
use crate::profiler::monitor::ResourceMonitor;
use crate::profiler::{CostModel, EnergyProfiler};
use crate::soc::device::{Device, ExecCtx, Snapshot};
use crate::soc::{Placement, Proc};
use crate::util::Prng;

use super::arena::RequestArena;
use super::event::Event;
use super::queue::EventQueue;

/// Select the cost model planning/scheduling sees.
pub fn cost_model<'a>(
    info: PlannerInfo,
    profiler: &'a EnergyProfiler,
    device: &'a Device,
) -> &'a dyn CostModel {
    match info {
        PlannerInfo::Profiler => profiler as &dyn CostModel,
        PlannerInfo::Oracle => device as &dyn CostModel,
    }
}

/// Per-request execution state (owned by [`ExecStage`]).
#[derive(Debug, Clone)]
pub struct Active {
    /// The admitted request.
    pub req: Request,
    /// Owning stream index (equals `req.stream`).
    pub model: usize,
    /// Next operator to execute.
    pub next_op: usize,
    /// When the next op's inputs are ready (virtual seconds).
    pub data_ready_s: f64,
    /// When the first op started (None until dispatched).
    pub start_s: Option<f64>,
    /// Dynamic energy attributed so far, joules.
    pub energy_j: f64,
    /// CPU-resident fraction of each op output produced so far.
    pub out_cpu: Vec<f64>,
    /// Placement of the previously executed op.
    pub prev_placement: Option<Placement>,
}

/// Per-stream partition plans plus their latency profiles (suffix sums of
/// predicted per-op latencies). Shared context the stages read and the
/// monitor/drift paths refresh — indexed by stream id, which the engine
/// requires to equal the stream's position.
pub struct PlanTable {
    plans: Vec<Plan>,
    profiles: Vec<Vec<f64>>,
    /// Per-stream plan generation, bumped on every [`PlanTable::set_plan`]
    /// — part of the profile-memo key.
    epochs: Vec<u64>,
    /// Memo key the current profile was computed under (`None` =
    /// recompute on the next refresh).
    memo: Vec<Option<ProfileKey>>,
}

/// Everything a refreshed latency profile depends on: the plan
/// generation, the cost model's correction version, and the snapshot
/// fields [`crate::profiler::features::extract`] reads (bitwise — the
/// memo must never treat two different float inputs as equal).
/// `Snapshot::time_s` is deliberately excluded: feature extraction never
/// reads it, so profiles are time-invariant under otherwise-identical
/// conditions — that invariance is exactly what makes the memo hit
/// across monitor ticks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ProfileKey {
    epoch: u64,
    model_version: u64,
    snap: [u64; 6],
}

/// The snapshot fields the cost features read, as raw bits.
fn snap_bits(snap: &Snapshot) -> [u64; 6] {
    [
        snap.cpu_freq_hz.to_bits(),
        snap.gpu_freq_hz.to_bits(),
        snap.cpu_util.to_bits(),
        snap.gpu_util.to_bits(),
        snap.temp_c.to_bits(),
        snap.bw_factor.to_bits(),
    ]
}

impl PlanTable {
    /// Build from parallel per-stream vectors.
    pub fn new(plans: Vec<Plan>, profiles: Vec<Vec<f64>>) -> PlanTable {
        debug_assert_eq!(plans.len(), profiles.len());
        let n = plans.len();
        PlanTable {
            plans,
            profiles,
            epochs: vec![0; n],
            memo: vec![None; n],
        }
    }

    /// The current plan of `stream`.
    pub fn plan(&self, stream: usize) -> &Plan {
        &self.plans[stream]
    }

    /// The current latency profile of `stream`: entry `i` is the predicted
    /// service time from op `i` (inclusive) to completion; entry
    /// `num_ops` is 0.
    pub fn profile(&self, stream: usize) -> &[f64] {
        &self.profiles[stream]
    }

    /// Replace the plan of `stream`.
    pub fn set_plan(&mut self, stream: usize, plan: Plan) {
        self.plans[stream] = plan;
        self.epochs[stream] += 1;
    }

    /// Replace the latency profile of `stream`.
    pub fn set_profile(&mut self, stream: usize, profile: Vec<f64>) {
        self.profiles[stream] = profile;
        // hand-set profiles carry no memo key: recompute on next refresh
        self.memo[stream] = None;
    }

    /// Compute the latency profile of `plan` under `model` at `snap`.
    pub fn profile_of(
        g: &ModelGraph,
        plan: &Plan,
        model: &dyn CostModel,
        snap: &Snapshot,
    ) -> Vec<f64> {
        let lat = per_op_latencies(g, &plan.placements, model, snap);
        let mut suffix = vec![0.0; lat.len() + 1];
        for i in (0..lat.len()).rev() {
            suffix[i] = suffix[i + 1] + lat[i];
        }
        suffix
    }

    /// Refresh every stream's profile against the live snapshot (monitor
    /// period boundary — keeps scheduler slack and admission backlog
    /// estimates tracking device dynamics).
    ///
    /// Profiles are **memoized**: when the cost model exposes a
    /// correction version ([`CostModel::version`]) and neither the plan,
    /// the version, nor the feature-relevant snapshot bits changed since
    /// the last refresh, the stored profile is provably the one a
    /// recompute would produce and the suffix-sum walk is skipped. A
    /// model without a version (`None` — e.g. the device oracle) always
    /// recomputes, byte-preserving the pre-memo behavior.
    pub fn refresh_profiles(
        &mut self,
        streams: &[StreamSpec],
        model: &dyn CostModel,
        snap: &Snapshot,
    ) {
        let versioned = model.version().map(|v| (v, snap_bits(snap)));
        for s in streams {
            let key = versioned.map(|(version, bits)| ProfileKey {
                epoch: self.epochs[s.id],
                model_version: version,
                snap: bits,
            });
            if key.is_some() && self.memo[s.id] == key {
                continue;
            }
            let profile = Self::profile_of(&s.model, &self.plans[s.id], model, snap);
            self.profiles[s.id] = profile;
            self.memo[s.id] = key;
        }
    }
}

/// Pre-generated arrival timeline. Seeds the [`EventQueue`] with one
/// [`Event::Arrival`] per request, preserving the legacy PRNG sequence
/// (one [`Prng::split`] per stream, in stream order) and the legacy
/// ordering (stable sort by arrival time ≡ heap `(time, seq)` order with
/// stream-major push order).
pub struct ArrivalSource {
    total: usize,
}

impl ArrivalSource {
    /// Generate all arrivals in `[0, duration_s)` and push them into
    /// `queue`. Fails when no stream produces a request.
    pub fn seed(
        queue: &mut EventQueue,
        streams: &[StreamSpec],
        duration_s: f64,
        seed: u64,
    ) -> Result<ArrivalSource> {
        let mut rng = Prng::new(seed);
        let mut total = 0usize;
        for s in streams {
            let mut r = rng.split();
            for (k, t) in s.arrival.timestamps(duration_s, &mut r).iter().enumerate() {
                queue.push(
                    *t,
                    Event::Arrival {
                        req: Request {
                            id: k * streams.len() + s.id,
                            stream: s.id,
                            arrival_s: *t,
                            deadline_s: *t + s.slo_s,
                        },
                        admitted: false,
                    },
                );
                total += 1;
            }
        }
        if total == 0 {
            bail!("duration too short: no requests generated");
        }
        Ok(ArrivalSource { total })
    }

    /// Seed the queue from *recorded* arrivals instead of the PRNG — the
    /// replay path. Requests must be pushed in the same order `seed`
    /// would have produced them (stream-major, chronological within a
    /// stream) so `(time, seq)` tie-breaks match the original run; the
    /// caller sorts by `(stream, id)` which is exactly that order.
    pub fn seed_recorded(queue: &mut EventQueue, arrivals: &[Request]) -> Result<ArrivalSource> {
        if arrivals.is_empty() {
            bail!("replay source contains no arrivals");
        }
        for req in arrivals {
            queue.push(
                req.arrival_s,
                Event::Arrival {
                    req: *req,
                    admitted: false,
                },
            );
        }
        Ok(ArrivalSource {
            total: arrivals.len(),
        })
    }

    /// Requests generated across all streams.
    pub fn total(&self) -> usize {
        self.total
    }
}

/// Admission in front of the queue: wraps [`AdmissionCtrl`], computing
/// its inputs (earliest start, predicted backlog of admitted work, the
/// request's predicted service time, same-stream in-flight count) from
/// the shared plan table and execution state.
pub struct AdmissionStage {
    ctrl: AdmissionCtrl,
}

impl AdmissionStage {
    /// Build with zeroed counters.
    pub fn new(policy: AdmissionPolicy) -> AdmissionStage {
        AdmissionStage {
            ctrl: AdmissionCtrl::new(policy),
        }
    }

    /// Counter snapshot.
    pub fn counters(&self) -> AdmissionCounters {
        self.ctrl.counters()
    }

    /// The applied policy.
    pub fn policy(&self) -> AdmissionPolicy {
        self.ctrl.policy()
    }

    /// Decide admission for one arrival; returns the ready-to-queue state
    /// for an admitted request, or `None` when it is shed.
    ///
    /// The decision is evaluated at the request's *arrival* time, not the
    /// (possibly earlier) device time: `now.max(req.arrival_s)` anchors
    /// the earliest-start estimate, and the backlog of admitted work is
    /// discounted by what the processors can retire before the request
    /// actually arrives ([`remaining_backlog_at`]) — a future-arriving
    /// request must not be shed against a backlog that will have drained
    /// by the time it shows up.
    #[allow(clippy::too_many_arguments)]
    pub fn try_admit(
        &mut self,
        req: Request,
        streams: &[StreamSpec],
        plans: &PlanTable,
        active: &[Active],
        avail: &[f64; 2],
        now_s: f64,
        arena: &mut RequestArena,
    ) -> Option<Active> {
        let now_eff = now_s.max(req.arrival_s);
        let est_start = now_eff.max(avail[0]).max(avail[1]);
        let backlog_raw: f64 = active
            .iter()
            .map(|a| plans.profile(a.model)[a.next_op])
            .sum();
        let backlog = remaining_backlog_at(backlog_raw, now_s, req.arrival_s, avail);
        let service = plans.profile(req.stream)[0];
        let in_stream = active.iter().filter(|a| a.req.stream == req.stream).count();
        if !self.ctrl.admit(&req, est_start, backlog, service, in_stream) {
            return None;
        }
        let g = &streams[req.stream].model;
        Some(Active {
            model: req.stream,
            next_op: 0,
            data_ready_s: req.arrival_s,
            start_s: None,
            energy_j: 0.0,
            out_cpu: arena.alloc(g.num_ops(), INPUT_CPU_FRAC),
            prev_placement: None,
            req,
        })
    }
}

/// The dispatch decision: which active request runs its next op, and the
/// earliest feasible start the pick was made at.
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    /// Index into the execution stage's active list.
    pub active_idx: usize,
    /// Earliest feasible start under the planned placement, virtual
    /// seconds (the execution stage clamps this against the device clock).
    pub start_s: f64,
}

/// Cached per-active-request dispatch facts (placement and predicted
/// remaining work of its next op). `None` = recompute from the plan table
/// on the next pick.
#[derive(Debug, Clone, Copy)]
struct Slot {
    placement: Placement,
    remaining_s: f64,
}

/// Dispatch-order policy over eligible ops: builds one [`Candidate`] per
/// active request and asks the configured [`Scheduler`] to pick.
///
/// Candidate facts that require plan-table lookups are cached per request
/// in slots and only recomputed when the engine signals that request's
/// state changed ([`DispatchStage::note_op_executed`]) or the whole table
/// moved ([`DispatchStage::invalidate_all`]) — the legacy loop paid two
/// hash lookups per active request per iteration instead.
pub struct DispatchStage {
    scheduler: Box<dyn Scheduler + Send + Sync>,
    slots: Vec<Option<Slot>>,
    cands: Vec<Candidate>,
}

impl DispatchStage {
    /// Build for a configured scheduler kind.
    pub fn new(kind: SchedulerKind) -> DispatchStage {
        DispatchStage {
            scheduler: by_kind(kind),
            slots: Vec::new(),
            cands: Vec::new(),
        }
    }

    /// The dispatch policy (the execution stage consults its placement
    /// override hook).
    pub fn scheduler(&self) -> &dyn Scheduler {
        self.scheduler.as_ref()
    }

    /// Policy name as it appears in reports.
    pub fn name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// Pick the next request to run an op for. `active` must be non-empty
    /// and aligned with the slots this stage was notified about.
    pub fn pick(&mut self, active: &[Active], plans: &PlanTable, avail: &[f64; 2]) -> Decision {
        self.pick_impl(active, plans, avail, None)
    }

    /// [`DispatchStage::pick`] with batch-hold floors applied: a candidate
    /// whose `(stream, op)` frontier is being held open by the
    /// [`crate::batching::Batcher`] may not start before the hold's
    /// release time — other streams' candidates keep their natural start
    /// and win dispatch in the meantime, and arrivals admitted before the
    /// release can still join the held batch. With no holds recorded this
    /// is identical to `pick` (the unbatched engine never calls it).
    pub fn pick_floored(
        &mut self,
        active: &[Active],
        plans: &PlanTable,
        avail: &[f64; 2],
        batcher: &crate::batching::Batcher,
    ) -> Decision {
        self.pick_impl(active, plans, avail, Some(batcher))
    }

    fn pick_impl(
        &mut self,
        active: &[Active],
        plans: &PlanTable,
        avail: &[f64; 2],
        batcher: Option<&crate::batching::Batcher>,
    ) -> Decision {
        debug_assert_eq!(self.slots.len(), active.len());
        self.cands.clear();
        for (ai, a) in active.iter().enumerate() {
            if self.slots[ai].is_none() {
                self.slots[ai] = Some(Slot {
                    placement: plans.plan(a.model).placements[a.next_op],
                    remaining_s: plans.profile(a.model)[a.next_op],
                });
            }
            let slot = self.slots[ai].expect("slot filled above");
            let mut start = a.data_ready_s;
            for p in Proc::ALL {
                if slot.placement.uses(p) {
                    start = start.max(avail[p.index()]);
                }
            }
            if let Some(release) = batcher.and_then(|b| b.floor(a.model, a.next_op)) {
                start = start.max(release);
            }
            self.cands.push(Candidate {
                active_idx: ai,
                start_s: start,
                arrival_s: a.req.arrival_s,
                deadline_s: a.req.deadline_s,
                remaining_s: slot.remaining_s,
            });
        }
        let chosen = self.cands[self.scheduler.pick(&self.cands)];
        Decision {
            active_idx: chosen.active_idx,
            start_s: chosen.start_s,
        }
    }

    /// An active request was admitted (appended to the active list).
    pub fn note_admitted(&mut self) {
        self.slots.push(None);
    }

    /// Request `ai` executed an op (its next-op facts changed).
    pub fn note_op_executed(&mut self, ai: usize) {
        self.slots[ai] = None;
    }

    /// Request `ai` completed and was `swap_remove`d from the active list.
    pub fn note_removed(&mut self, ai: usize) {
        self.slots.swap_remove(ai);
    }

    /// Plans or profiles changed for every stream (regime re-plan, drift
    /// re-plan, or monitor profile refresh).
    pub fn invalidate_all(&mut self) {
        for s in &mut self.slots {
            *s = None;
        }
    }
}

/// What one executed operator produced (event material for the driver).
#[derive(Debug, Clone)]
pub struct OpRecord {
    /// Owning request id.
    pub request: usize,
    /// Owning stream id.
    pub stream: usize,
    /// Operator index.
    pub op: usize,
    /// Clamped start time the op ran at.
    pub start_s: f64,
    /// Completion time (`start + latency`).
    pub end_s: f64,
    /// Measured latency, seconds.
    pub latency_s: f64,
    /// Measured dynamic energy, joules.
    pub energy_j: f64,
    /// Placement the op actually ran with.
    pub placement: Placement,
}

/// Op execution and accounting: owns the active list, per-processor
/// availability/busy accounting, latency/energy recorders, and completed
/// outcomes.
#[derive(Default)]
pub struct ExecStage {
    active: Vec<Active>,
    avail: [f64; 2],
    busy_acc: [f64; 2],
    latencies: LatencyRecorder,
    energy: EnergyAccount,
    outcomes: Vec<RequestOutcome>,
    cpu_busy_total: f64,
    gpu_busy_total: f64,
    /// Reused backing store for the per-dispatch `input_cpu_fracs`
    /// vector (one heap allocation for the whole run instead of one per
    /// executed op).
    scratch: Vec<f64>,
}

impl ExecStage {
    /// Empty stage.
    pub fn new() -> ExecStage {
        ExecStage::default()
    }

    /// Whether any admitted request is unfinished.
    pub fn has_active(&self) -> bool {
        !self.active.is_empty()
    }

    /// The admitted-but-unfinished requests.
    pub fn active(&self) -> &[Active] {
        &self.active
    }

    /// Per-processor availability times (when each becomes free).
    pub fn avail(&self) -> &[f64; 2] {
        &self.avail
    }

    /// Enqueue an admitted request.
    pub fn admit(&mut self, a: Active) {
        self.active.push(a);
    }

    /// Charge virtual partitioning-decision time to the CPU timeline (the
    /// partitioner runs on the phone's CPU in real deployments).
    pub fn charge_cpu_decision(&mut self, dt_s: f64) {
        self.avail[Proc::Cpu.index()] += dt_s;
    }

    /// Advance the device clock to `start_s` (crediting accumulated busy
    /// time as utilization), or clamp the start to the clock when the
    /// requested start is already in the past. Returns the effective
    /// start time.
    pub fn advance_to(&mut self, device: &mut Device, start_s: f64) -> f64 {
        let now = device.time_s();
        if start_s > now {
            let dt = start_s - now;
            let u_cpu = (self.busy_acc[0] / dt).min(1.0);
            let u_gpu = (self.busy_acc[1] / dt).min(1.0);
            self.busy_acc = [0.0, 0.0];
            device.advance(dt, u_cpu, u_gpu);
            start_s
        } else {
            now
        }
    }

    /// Execute the next op of `active[ai]` at (clamped) `start_s`: run the
    /// scheduler's placement override through its feasibility check,
    /// measure on the device, feed the profiler, and account energy and
    /// busy time.
    #[allow(clippy::too_many_arguments)]
    pub fn execute(
        &mut self,
        ai: usize,
        start_s: f64,
        streams: &[StreamSpec],
        plans: &PlanTable,
        device: &mut Device,
        profiler: &mut EnergyProfiler,
        scheduler: &dyn Scheduler,
        info: PlannerInfo,
        numerics: &mut Option<NumericsHook>,
    ) -> Result<OpRecord> {
        let others_running = self.active.len() > 1;
        let stream = self.active[ai].model;
        let op_idx = self.active[ai].next_op;
        let req_id = self.active[ai].req.id;
        let deadline_s = self.active[ai].req.deadline_s;
        let g: &ModelGraph = &streams[stream].model;
        let op = &g.ops[op_idx];
        let planned = plans.plan(stream).placements[op_idx];
        let mut input_cpu_fracs = std::mem::take(&mut self.scratch);
        input_cpu_fracs.clear();
        if op.inputs.is_empty() {
            input_cpu_fracs.resize(op.in_shapes.len(), INPUT_CPU_FRAC);
        } else {
            let a = &self.active[ai];
            input_cpu_fracs.extend(op.inputs.iter().map(|&j| a.out_cpu[j]));
        }
        let (new_run_cpu, new_run_gpu) = match self.active[ai].prev_placement {
            None => (true, true),
            Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
        };
        // slack if the op starts now: time to spare before the deadline
        // after the predicted remaining work (this op inclusive)
        let slack_s = deadline_s - (start_s + plans.profile(stream)[op_idx]);
        let ctx = ExecCtx {
            input_cpu_fracs,
            new_run_cpu,
            new_run_gpu,
            concurrent: others_running,
        };
        let snap = device.snapshot();
        let placement = {
            let model = cost_model(info, profiler, device);
            let wanted = scheduler.place(planned, op, &ctx, &snap, model, slack_s);
            // `start_s` was clamped against the *planned* placement's
            // processors only; an override may not claim a processor that
            // is still busy at `start_s` (it would double-book and rewind
            // avail) — fall back to the plan in that case
            let feasible = Proc::ALL
                .iter()
                .all(|&p| !wanted.uses(p) || self.avail[p.index()] <= start_s);
            if feasible {
                wanted
            } else {
                planned
            }
        };
        let measured = device.measure(op, placement, &ctx);
        profiler.observe(op, placement, &ctx, &snap, &measured);
        // ctx is done with the fracs — reclaim the buffer for next dispatch
        self.scratch = ctx.input_cpu_fracs;
        self.energy.add_op(&measured);
        {
            let a = &mut self.active[ai];
            a.energy_j += measured.energy_j;
            if a.start_s.is_none() {
                a.start_s = Some(start_s);
            }
            a.out_cpu[op_idx] = placement.frac_on(Proc::Cpu);
            a.prev_placement = Some(placement);
            a.data_ready_s = start_s + measured.latency_s;
        }
        for p in Proc::ALL {
            if placement.uses(p) {
                self.avail[p.index()] = start_s + measured.latency_s;
                self.busy_acc[p.index()] += measured.latency_s;
            }
        }
        self.cpu_busy_total += measured.cpu_busy_s;
        self.gpu_busy_total += measured.gpu_busy_s;
        if let Some(hook) = numerics.as_mut() {
            hook(&self.active[ai].req, op)?;
        }
        self.active[ai].next_op += 1;
        Ok(OpRecord {
            request: req_id,
            stream,
            op: op_idx,
            start_s,
            end_s: start_s + measured.latency_s,
            latency_s: measured.latency_s,
            energy_j: measured.energy_j,
            placement,
        })
    }

    /// Execute the next op of every request in `members` as **one batched
    /// dispatch** at (clamped) `start_s` (see [`crate::batching`]). All
    /// members must belong to the same stream and sit at the same op
    /// frontier with inputs ready by `start_s`; a single-member batch is
    /// exactly [`ExecStage::execute`].
    ///
    /// The device measures the batch once
    /// ([`crate::soc::device::Device::measure_batch`]: transfer per member,
    /// sub-linear compute growth, dispatch paid once); every member
    /// advances to the same completion time (batched requests finish
    /// together — the responsiveness cost the batch policy weighed), the
    /// batch's energy is attributed in equal per-member shares, and the
    /// profiler is fed a de-batched per-request estimate
    /// ([`crate::batching::cost::debatch_op_cost`]) so the drift corrector
    /// keeps learning single-request residuals. Returns one [`OpRecord`]
    /// per member, in member order.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_batch(
        &mut self,
        members: &[usize],
        start_s: f64,
        streams: &[StreamSpec],
        plans: &PlanTable,
        device: &mut Device,
        profiler: &mut EnergyProfiler,
        scheduler: &dyn Scheduler,
        info: PlannerInfo,
        numerics: &mut Option<NumericsHook>,
    ) -> Result<Vec<OpRecord>> {
        assert!(!members.is_empty(), "empty batch");
        if members.len() == 1 {
            return Ok(vec![self.execute(
                members[0], start_s, streams, plans, device, profiler, scheduler, info,
                numerics,
            )?]);
        }
        let batch = members.len();
        let stream = self.active[members[0]].model;
        let op_idx = self.active[members[0]].next_op;
        debug_assert!(members
            .iter()
            .all(|&ai| self.active[ai].model == stream && self.active[ai].next_op == op_idx));
        let others_running = self.active.len() > batch;
        let g: &ModelGraph = &streams[stream].model;
        let op = &g.ops[op_idx];
        let planned = plans.plan(stream).placements[op_idx];
        // the lead (oldest) member's residency and run-continuation flags
        // stand in for the batch: members move in lockstep under the same
        // plan, so their residencies agree except after per-member
        // placement overrides, which the batch path never takes apart
        let mut input_cpu_fracs = std::mem::take(&mut self.scratch);
        input_cpu_fracs.clear();
        let lead = &self.active[members[0]];
        if op.inputs.is_empty() {
            input_cpu_fracs.resize(op.in_shapes.len(), INPUT_CPU_FRAC);
        } else {
            input_cpu_fracs.extend(op.inputs.iter().map(|&j| lead.out_cpu[j]));
        }
        let (new_run_cpu, new_run_gpu) = match lead.prev_placement {
            None => (true, true),
            Some(p) => (!p.uses(Proc::Cpu), !p.uses(Proc::Gpu)),
        };
        // the tightest member's slack governs the energy-placement override
        let slack_s = members
            .iter()
            .map(|&ai| self.active[ai].req.deadline_s)
            .fold(f64::INFINITY, f64::min)
            - (start_s + plans.profile(stream)[op_idx]);
        let ctx = ExecCtx {
            input_cpu_fracs,
            new_run_cpu,
            new_run_gpu,
            concurrent: others_running,
        };
        let snap = device.snapshot();
        let placement = {
            let model = cost_model(info, profiler, device);
            let wanted = scheduler.place(planned, op, &ctx, &snap, model, slack_s);
            let feasible = Proc::ALL
                .iter()
                .all(|&p| !wanted.uses(p) || self.avail[p.index()] <= start_s);
            if feasible {
                wanted
            } else {
                planned
            }
        };
        let measured = device.measure_batch(op, placement, &ctx, batch);
        let per_request = crate::batching::cost::debatch_op_cost(&measured, batch);
        profiler.observe(op, placement, &ctx, &snap, &per_request);
        // ctx is done with the fracs — reclaim the buffer for next dispatch
        self.scratch = ctx.input_cpu_fracs;
        self.energy.add_op(&measured);
        let end_s = start_s + measured.latency_s;
        let share_j = measured.energy_j / batch as f64;
        let mut records = Vec::with_capacity(batch);
        for &ai in members {
            let a = &mut self.active[ai];
            a.energy_j += share_j;
            if a.start_s.is_none() {
                a.start_s = Some(start_s);
            }
            a.out_cpu[op_idx] = placement.frac_on(Proc::Cpu);
            a.prev_placement = Some(placement);
            a.data_ready_s = end_s;
            records.push(OpRecord {
                request: a.req.id,
                stream,
                op: op_idx,
                start_s,
                end_s,
                latency_s: measured.latency_s,
                energy_j: share_j,
                placement,
            });
        }
        for p in Proc::ALL {
            if placement.uses(p) {
                self.avail[p.index()] = end_s;
                self.busy_acc[p.index()] += measured.latency_s;
            }
        }
        self.cpu_busy_total += measured.cpu_busy_s;
        self.gpu_busy_total += measured.gpu_busy_s;
        if let Some(hook) = numerics.as_mut() {
            for &ai in members {
                hook(&self.active[ai].req, op)?;
            }
        }
        for &ai in members {
            self.active[ai].next_op += 1;
        }
        Ok(records)
    }

    /// If `active[ai]` just ran its last op, retire it: record latency and
    /// deadline outcome, close the energy account, recycle its `out_cpu`
    /// buffer into `arena`, and return the outcome.
    pub fn complete_if_done(&mut self, ai: usize, arena: &mut RequestArena) -> Option<RequestOutcome> {
        if self.active[ai].next_op < self.active[ai].out_cpu.len() {
            return None;
        }
        let mut a = self.active.swap_remove(ai);
        arena.recycle(std::mem::take(&mut a.out_cpu));
        let outcome = RequestOutcome {
            start_s: a.start_s.expect("completed request must have started"),
            finish_s: a.data_ready_s,
            energy_j: a.energy_j,
            request: a.req,
        };
        self.latencies
            .record(outcome.latency_s(), outcome.queue_s(), outcome.met_deadline());
        self.energy.finish_inference();
        self.outcomes.push(outcome);
        Some(outcome)
    }

    /// Latency/deadline recorder (report assembly).
    pub fn latencies(&self) -> &LatencyRecorder {
        &self.latencies
    }

    /// Energy account (report assembly).
    pub fn energy(&self) -> &EnergyAccount {
        &self.energy
    }

    /// Completed-request outcomes, in completion order.
    pub fn outcomes(&self) -> &[RequestOutcome] {
        &self.outcomes
    }

    /// Total CPU busy-seconds measured across executed ops.
    pub fn cpu_busy_total(&self) -> f64 {
        self.cpu_busy_total
    }

    /// Total GPU busy-seconds measured across executed ops.
    pub fn gpu_busy_total(&self) -> f64 {
        self.gpu_busy_total
    }
}

/// Outcome of a monitor tick.
pub struct TickOutcome {
    /// Whether the sample flagged a regime change.
    pub regime_changed: bool,
    /// Re-plans adopted this tick: `(stream, virtual decision seconds,
    /// measured solve wall-clock seconds — telemetry only)`.
    pub replans: Vec<(usize, f64, f64)>,
}

/// Monitor-tick bookkeeping, regime-change re-planning, profile refresh,
/// and the drift fast path.
///
/// The [`ResourceMonitor`] itself (the sample history regime detection
/// compares against) lives on the engine — like the profiler, it is
/// device-lifetime state that must persist across runs. This stage owns
/// only the per-run tick schedule.
pub struct MonitorStage {
    period_s: f64,
    last_s: f64,
}

impl MonitorStage {
    /// Build with the configured sampling period.
    pub fn new(period_s: f64) -> MonitorStage {
        MonitorStage {
            period_s,
            last_s: 0.0,
        }
    }

    /// Fire the monitor tick if its due time (`last sample + period`) has
    /// been reached by the device clock. On a regime change every stream
    /// is re-planned (served from `cache` when the condition recurs);
    /// profiles always refresh against the live snapshot so scheduler
    /// slack and admission backlog estimates track device dynamics.
    /// `batch_hint` is the batch size planning prices ops at (1 without
    /// batching): regime re-plans run through a
    /// [`crate::batching::BatchedCostModel`] wrapper and key the plan
    /// cache under the matching batch bucket, while the latency-profile
    /// refresh below stays single-request (the batch policy scales
    /// profiles itself when predicting batched service times).
    #[allow(clippy::too_many_arguments)]
    pub fn maybe_tick(
        &mut self,
        monitor: &mut ResourceMonitor,
        device: &Device,
        profiler: &mut EnergyProfiler,
        policy: &dyn crate::partition::plan::Partitioner,
        controller: &mut RepartitionController,
        cache: &mut PlanCache,
        plans: &mut PlanTable,
        streams: &[StreamSpec],
        info: PlannerInfo,
        objective: crate::partition::plan::Objective,
        batch_hint: usize,
    ) -> Option<TickOutcome> {
        if device.time_s() - self.last_s < self.period_s {
            return None;
        }
        self.last_s = device.time_s();
        monitor.sample(device.snapshot());
        let regime_changed = monitor.regime_changed();
        let mut replans = Vec::new();
        if regime_changed {
            profiler.reset_correction();
            let snap = device.snapshot();
            for s in streams {
                let model = cost_model(info, profiler, device);
                let batched;
                let planning: &dyn CostModel = if batch_hint > 1 {
                    batched = crate::batching::BatchedCostModel::new(model, batch_hint);
                    &batched
                } else {
                    model
                };
                if let Some((plan, dt)) = controller.on_regime_change(
                    &s.model,
                    policy,
                    planning,
                    &snap,
                    objective,
                    batch_hint,
                    Some(&mut *cache),
                ) {
                    plans.set_plan(s.id, plan);
                    replans.push((s.id, dt, controller.last_solve_wall_s()));
                }
            }
        }
        // refresh after any regime re-plan so profiles match the adopted
        // plans and the live snapshot (drift, background)
        let snap = device.snapshot();
        let model = cost_model(info, profiler, device);
        plans.refresh_profiles(streams, model, &snap);
        Some(TickOutcome {
            regime_changed,
            replans,
        })
    }

    /// Drift fast path (AdaOper only): when the profiler flags sustained
    /// residual drift, re-solve a window at the execution frontier of the
    /// request that just ran. Returns `(stream, virtual decision seconds)`
    /// when a re-plan was adopted.
    #[allow(clippy::too_many_arguments)]
    pub fn maybe_drift(
        &mut self,
        ai: usize,
        active: &[Active],
        streams: &[StreamSpec],
        device: &Device,
        profiler: &EnergyProfiler,
        controller: &mut RepartitionController,
        plans: &mut PlanTable,
        policy_kind: PolicyKind,
        info: PlannerInfo,
        batch_hint: usize,
    ) -> Option<(usize, f64)> {
        if !matches!(policy_kind, PolicyKind::AdaOper) || !profiler.drifted() {
            return None;
        }
        let a = &active[ai];
        let g: &ModelGraph = &streams[a.model].model;
        let snap = device.snapshot();
        let model = cost_model(info, profiler, device);
        let batched;
        let planning: &dyn CostModel = if batch_hint > 1 {
            batched = crate::batching::BatchedCostModel::new(model, batch_hint);
            &batched
        } else {
            model
        };
        let (plan, dt) = controller.on_drift(
            g,
            plans.plan(a.model),
            a.next_op,
            planning,
            &snap,
            Some(&a.out_cpu),
        )?;
        let profile = PlanTable::profile_of(g, &plan, model, &snap);
        plans.set_profile(a.model, profile);
        plans.set_plan(a.model, plan);
        Some((a.model, dt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::workload::Arrival;

    fn spec_stream() -> Vec<StreamSpec> {
        vec![StreamSpec::new(
            0,
            zoo::yolov2_tiny(),
            Arrival::Poisson { hz: 5.0 },
            0.5,
        )]
    }

    fn table(profile: Vec<f64>, num_ops: usize) -> PlanTable {
        let plan = Plan {
            placements: vec![Placement::GPU; num_ops],
            predicted: Default::default(),
            policy: "t".into(),
        };
        PlanTable::new(vec![plan], vec![profile])
    }

    fn active_at(next_op: usize, num_ops: usize) -> Active {
        Active {
            req: Request {
                id: 0,
                stream: 0,
                arrival_s: 0.0,
                deadline_s: 9.9,
            },
            model: 0,
            next_op,
            data_ready_s: 1.0,
            start_s: Some(0.5),
            energy_j: 0.0,
            out_cpu: vec![INPUT_CPU_FRAC; num_ops],
            prev_placement: None,
        }
    }

    #[test]
    fn admission_does_not_shed_future_arrival_against_drained_backlog() {
        let g = zoo::yolov2_tiny();
        let n = g.num_ops();
        // active request has 0.5 s of predicted remaining work; a new
        // request costs 0.2 s end to end
        let mut profile = vec![0.0; n + 1];
        profile[0] = 0.2;
        profile[1] = 0.5;
        let streams = spec_stream();
        let plans = table(profile, n);
        let active = vec![active_at(1, n)];
        let avail = [1.0, 1.0];
        let mut adm = AdmissionStage::new(AdmissionPolicy::DropLate);
        let mut arena = RequestArena::new();

        // arriving far in the future: today's backlog drains before it,
        // so the request is feasible and must be admitted (regression for
        // the drop-late skew that charged undrained backlog)
        let future = Request {
            id: 1,
            stream: 0,
            arrival_s: 10.0,
            deadline_s: 10.5,
        };
        assert!(
            adm.try_admit(future, &streams, &plans, &active, &avail, 1.0, &mut arena)
                .is_some(),
            "future-arriving request spuriously shed"
        );

        // the same deadline headroom arriving *now* is infeasible: the
        // backlog has had no time to drain
        let now = Request {
            id: 2,
            stream: 0,
            arrival_s: 1.0,
            deadline_s: 1.5,
        };
        assert!(adm
            .try_admit(now, &streams, &plans, &active, &avail, 1.0, &mut arena)
            .is_none());
        let c = adm.counters();
        assert_eq!((c.offered, c.admitted, c.shed_late), (2, 1, 1));
    }

    #[test]
    fn arrival_source_seeds_sorted_requests_with_stable_ids() {
        let mut queue = EventQueue::new();
        let src = ArrivalSource::seed(&mut queue, &spec_stream(), 4.0, 7).unwrap();
        assert_eq!(src.total(), queue.len());
        assert!(src.total() > 0);
        let mut last = f64::NEG_INFINITY;
        let mut seen = 0;
        while let Some((t, ev)) = queue.pop() {
            let Event::Arrival { req, .. } = ev else {
                panic!("non-arrival event in seeded queue")
            };
            assert!(t >= last, "arrivals out of order: {t} after {last}");
            assert!((req.deadline_s - (req.arrival_s + 0.5)).abs() < 1e-12);
            assert_eq!(req.stream, 0);
            last = t;
            seen += 1;
        }
        assert_eq!(seen, src.total());
    }

    #[test]
    fn arrival_source_rejects_empty_horizon() {
        let mut queue = EventQueue::new();
        let streams = vec![StreamSpec::new(
            0,
            zoo::yolov2_tiny(),
            Arrival::Periodic { hz: 0.001, jitter: 0.0 },
            0.5,
        )];
        assert!(ArrivalSource::seed(&mut queue, &streams, 0.0001, 7).is_err());
    }

    #[test]
    fn dispatch_stage_candidates_track_availability() {
        let n = zoo::yolov2_tiny().num_ops();
        let mut profile = vec![0.0; n + 1];
        profile[0] = 0.3;
        let plans = table(profile, n);
        let mut d = DispatchStage::new(SchedulerKind::Fifo);
        d.note_admitted();
        let mut a = active_at(0, n);
        a.data_ready_s = 0.2;
        let active = vec![a];
        // GPU busy until 1.5 and the plan places op 0 on the GPU → the
        // candidate start is pushed to 1.5; CPU availability is ignored
        let dec = d.pick(&active, &plans, &[9.0, 1.5]);
        assert_eq!(dec.active_idx, 0);
        assert_eq!(dec.start_s, 1.5);
        // slot caches survive a pick but follow availability changes
        let dec = d.pick(&active, &plans, &[9.0, 2.5]);
        assert_eq!(dec.start_s, 2.5);
    }
}
