//! Opt-in wall-clock self-profiling of the sim kernel's stages.
//!
//! The serving engine spends its wall time in a handful of stages —
//! arrival generation, admission, dispatch selection, op execution, the
//! resource monitor, and event-queue bookkeeping. [`StageTimers`] wraps
//! each with a monotonic-clock lap counter so `adaoper inspect --stages`
//! and the hot-loop bench trajectory can say where the time actually
//! goes (ROADMAP item 4's 10× events/sec target needs exactly this).
//!
//! These timers measure **host wall time only**: they never read or
//! advance virtual time, so enabling them cannot change a single
//! simulated byte. They are off by default; the engine only laps them
//! when telemetry was explicitly enabled.

use std::time::Instant;

/// A sim-kernel stage the engine laps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Arrival generation / event-queue pops.
    Arrival,
    /// Admission control.
    Admission,
    /// Dispatch candidate selection.
    Dispatch,
    /// Operator execution (device model + energy accounting).
    Exec,
    /// Resource-monitor ticks and drift checks (incl. any replanning).
    Monitor,
    /// Event-queue and batch-queue bookkeeping.
    Queue,
}

impl Stage {
    /// Number of stages (array sizing).
    pub const COUNT: usize = 6;

    /// Every stage, in index order.
    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::Arrival,
        Stage::Admission,
        Stage::Dispatch,
        Stage::Exec,
        Stage::Monitor,
        Stage::Queue,
    ];

    /// Dense index for per-stage arrays.
    pub fn index(self) -> usize {
        match self {
            Stage::Arrival => 0,
            Stage::Admission => 1,
            Stage::Dispatch => 2,
            Stage::Exec => 3,
            Stage::Monitor => 4,
            Stage::Queue => 5,
        }
    }

    /// Lowercase name (report keys and JSON fields).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Arrival => "arrival",
            Stage::Admission => "admission",
            Stage::Dispatch => "dispatch",
            Stage::Exec => "exec",
            Stage::Monitor => "monitor",
            Stage::Queue => "queue",
        }
    }
}

/// Accumulated wall-clock laps per stage.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimers {
    secs: [f64; Stage::COUNT],
    calls: [u64; Stage::COUNT],
}

impl StageTimers {
    /// Zeroed timers.
    pub fn new() -> StageTimers {
        StageTimers::default()
    }

    /// Start a lap iff timers are enabled (`None` otherwise, costing one
    /// branch). Pair with [`StageTimers::stop`].
    pub fn start(timers: &Option<StageTimers>) -> Option<Instant> {
        timers.as_ref().map(|_| Instant::now())
    }

    /// Close a lap opened by [`StageTimers::start`].
    pub fn stop(timers: &mut Option<StageTimers>, stage: Stage, started: Option<Instant>) {
        if let (Some(t), Some(t0)) = (timers.as_mut(), started) {
            t.add(stage, t0.elapsed().as_secs_f64());
        }
    }

    /// Record one lap of `secs` against a stage.
    pub fn add(&mut self, stage: Stage, secs: f64) {
        self.secs[stage.index()] += secs;
        self.calls[stage.index()] += 1;
    }

    /// Fold a pre-aggregated lap tally back in (used when rebuilding a
    /// timer set from a parsed `stage_timers` trace line, where the call
    /// count is already summed).
    pub fn accumulate(&mut self, stage: Stage, calls: u64, secs: f64) {
        self.secs[stage.index()] += secs;
        self.calls[stage.index()] += calls;
    }

    /// Accumulated seconds in a stage.
    pub fn secs(&self, stage: Stage) -> f64 {
        self.secs[stage.index()]
    }

    /// Laps recorded against a stage.
    pub fn calls(&self, stage: Stage) -> u64 {
        self.calls[stage.index()]
    }

    /// Wall seconds across all stages.
    pub fn total_s(&self) -> f64 {
        self.secs.iter().sum()
    }

    /// Fold another run's laps into this one.
    pub fn merge(&mut self, other: &StageTimers) {
        for i in 0..Stage::COUNT {
            self.secs[i] += other.secs[i];
            self.calls[i] += other.calls[i];
        }
    }

    /// The per-stage laps as a JSON object fragment,
    /// `{"arrival":{"calls":N,"secs":S}, …}` — embedded in both the
    /// `stage_timers` trace line and the bench trajectory record.
    pub fn json_object(&self) -> String {
        let mut s = String::from("{");
        for (i, stage) in Stage::ALL.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let secs = self.secs[stage.index()];
            s.push_str(&format!(
                "\"{}\":{{\"calls\":{},\"secs\":{}}}",
                stage.name(),
                self.calls[stage.index()],
                if secs.is_finite() { format!("{secs}") } else { "null".to_string() }
            ));
        }
        s.push('}');
        s
    }

    /// The full `stage_timers` JSONL trace line.
    pub fn jsonl(&self) -> String {
        format!("{{\"event\":\"stage_timers\",\"stages\":{}}}", self.json_object())
    }

    /// Human-readable table (for `adaoper inspect --stages`).
    pub fn render(&self) -> String {
        let total = self.total_s();
        let mut s = format!("{:<10} {:>10} {:>12} {:>8}\n", "stage", "calls", "wall ms", "share");
        for stage in Stage::ALL {
            let secs = self.secs(stage);
            let share = if total > 0.0 { secs / total * 100.0 } else { 0.0 };
            s.push_str(&format!(
                "{:<10} {:>10} {:>12.3} {:>7.1}%\n",
                stage.name(),
                self.calls(stage),
                secs * 1e3,
                share
            ));
        }
        s.push_str(&format!("{:<10} {:>10} {:>12.3}\n", "total", "", total * 1e3));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_merge_accumulate() {
        let mut a = StageTimers::new();
        a.add(Stage::Exec, 0.5);
        a.add(Stage::Exec, 0.25);
        a.add(Stage::Monitor, 0.1);
        let mut b = StageTimers::new();
        b.add(Stage::Exec, 1.0);
        a.merge(&b);
        assert_eq!(a.calls(Stage::Exec), 3);
        assert!((a.secs(Stage::Exec) - 1.75).abs() < 1e-12);
        assert!((a.total_s() - 1.85).abs() < 1e-12);
    }

    #[test]
    fn start_stop_disabled_is_a_noop() {
        let mut timers: Option<StageTimers> = None;
        let t0 = StageTimers::start(&timers);
        assert!(t0.is_none());
        StageTimers::stop(&mut timers, Stage::Arrival, t0);
        assert!(timers.is_none());
    }

    #[test]
    fn start_stop_enabled_laps() {
        let mut timers = Some(StageTimers::new());
        let t0 = StageTimers::start(&timers);
        StageTimers::stop(&mut timers, Stage::Dispatch, t0);
        let t = timers.unwrap();
        assert_eq!(t.calls(Stage::Dispatch), 1);
        assert!(t.secs(Stage::Dispatch) >= 0.0);
    }

    #[test]
    fn json_object_parses_and_names_every_stage() {
        let mut t = StageTimers::new();
        t.add(Stage::Queue, 0.002);
        let v = crate::util::json::Json::parse(&t.jsonl()).unwrap();
        assert_eq!(v.need_str("event").unwrap(), "stage_timers");
        let stages = v.get("stages").unwrap();
        for stage in Stage::ALL {
            let entry = stages.get(stage.name()).unwrap();
            assert!(entry.need_u64("calls").is_ok(), "{}", stage.name());
        }
        assert_eq!(stages.get("queue").unwrap().need_f64("secs").unwrap(), 0.002);
    }

    #[test]
    fn render_mentions_every_stage() {
        let t = StageTimers::new();
        let out = t.render();
        for stage in Stage::ALL {
            assert!(out.contains(stage.name()), "{out}");
        }
        assert!(out.contains("total"));
    }
}
