//! Stochastic background workload and the hidden drift process.
//!
//! Two mechanisms shape dynamic device conditions:
//!
//! 1. **Background utilization** — other apps stealing CPU/GPU cycles.
//!    Modeled as a mean-reverting (Ornstein–Uhlenbeck) base level plus a
//!    two-state Markov *burst* process (e.g. a sync job waking up). The
//!    mean level is observable through the resource monitor (like
//!    `/proc/stat`); the instantaneous burst is only visible after the
//!    fact, through its effect on op latency/energy.
//!
//! 2. **Hidden drift** — a slowly wandering multiplicative factor on true
//!    energy/latency (thermal/memory-contention effects no static feature
//!    captures). This is deliberately *not* exposed in [`crate::soc::Snapshot`]:
//!    static predictors (GBDT) cannot see it, the paper's GRU corrector
//!    must infer it from recent prediction residuals.

use crate::util::Prng;

/// Ornstein–Uhlenbeck + Markov-burst utilization process.
#[derive(Debug, Clone)]
pub struct BackgroundLoad {
    /// Long-run mean utilization (the workload condition sets this).
    pub mean: f64,
    /// OU reversion rate (1/s).
    pub theta: f64,
    /// OU noise scale.
    pub sigma: f64,
    /// Burst height added on top while bursting.
    pub burst_height: f64,
    /// Rate of entering a burst (1/s).
    pub burst_on_rate: f64,
    /// Rate of leaving a burst (1/s).
    pub burst_off_rate: f64,
    level: f64,
    bursting: bool,
}

impl BackgroundLoad {
    /// Build an OU load around `mean` with burst episodes of `burst_height`.
    pub fn new(mean: f64, sigma: f64, burst_height: f64) -> Self {
        BackgroundLoad {
            mean,
            theta: 0.8,
            sigma,
            burst_height,
            burst_on_rate: 0.25,
            burst_off_rate: 1.2,
            level: mean,
            bursting: false,
        }
    }

    /// Quiet device.
    pub fn idle() -> Self {
        BackgroundLoad::new(0.05, 0.02, 0.05)
    }

    /// Advance by `dt` seconds.
    pub fn step(&mut self, dt: f64, rng: &mut Prng) {
        // OU: dX = θ(μ−X)dt + σ√dt · N(0,1)
        self.level += self.theta * (self.mean - self.level) * dt
            + self.sigma * dt.sqrt() * rng.normal();
        self.level = self.level.clamp(0.0, 0.95);
        // Markov burst switching
        let p_switch = if self.bursting {
            1.0 - (-self.burst_off_rate * dt).exp()
        } else {
            1.0 - (-self.burst_on_rate * dt).exp()
        };
        if rng.chance(p_switch) {
            self.bursting = !self.bursting;
        }
    }

    /// Instantaneous utilization (what actually steals cycles *now*).
    pub fn instant(&self) -> f64 {
        (self.level + if self.bursting { self.burst_height } else { 0.0 }).clamp(0.0, 0.95)
    }

    /// Smoothed utilization (what a /proc/stat-style monitor reports:
    /// the OU level without the instantaneous burst state).
    pub fn observable(&self) -> f64 {
        self.level.clamp(0.0, 0.95)
    }

    /// Whether a burst episode is currently active.
    pub fn is_bursting(&self) -> bool {
        self.bursting
    }

    /// Re-target the long-run mean (workload condition switch).
    pub fn set_mean(&mut self, mean: f64) {
        self.mean = mean.clamp(0.0, 0.95);
        self.level = self.mean; // snap — condition presets pin the level
    }
}

/// Slow multiplicative drift on true cost, hidden from snapshots.
/// log-factor follows an OU process; factor = exp(x) stays near 1.
#[derive(Debug, Clone)]
pub struct HiddenDrift {
    log_factor: f64,
    theta: f64,
    sigma: f64,
}

impl HiddenDrift {
    /// Build at factor 1 with the given OU sigma.
    pub fn new(sigma: f64) -> Self {
        HiddenDrift {
            log_factor: 0.0,
            theta: 0.15,
            sigma,
        }
    }

    /// Advance the OU log-factor by `dt`.
    pub fn step(&mut self, dt: f64, rng: &mut Prng) {
        self.log_factor += -self.theta * self.log_factor * dt
            + self.sigma * dt.sqrt() * rng.normal();
        self.log_factor = self.log_factor.clamp(-0.5, 0.5);
    }

    /// Current multiplicative factor (≈ 0.6 – 1.65).
    pub fn factor(&self) -> f64 {
        self.log_factor.exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ou_reverts_to_mean() {
        let mut bg = BackgroundLoad::new(0.5, 0.05, 0.2);
        let mut rng = Prng::new(3);
        let mut sum = 0.0;
        let n = 20_000;
        for _ in 0..n {
            bg.step(0.01, &mut rng);
            sum += bg.observable();
        }
        let avg = sum / n as f64;
        assert!((avg - 0.5).abs() < 0.05, "avg {avg}");
    }

    #[test]
    fn bursts_happen_and_end() {
        let mut bg = BackgroundLoad::new(0.3, 0.02, 0.3);
        let mut rng = Prng::new(4);
        let (mut on, mut off) = (0usize, 0usize);
        for _ in 0..50_000 {
            bg.step(0.01, &mut rng);
            if bg.is_bursting() {
                on += 1;
            } else {
                off += 1;
            }
        }
        assert!(on > 1000, "never bursts");
        assert!(off > 1000, "always bursts");
        // expected duty cycle ≈ on_rate/(on_rate+off_rate) ≈ 0.17
        let duty = on as f64 / (on + off) as f64;
        assert!((0.05..0.4).contains(&duty), "duty {duty}");
    }

    #[test]
    fn instant_geq_observable_during_burst() {
        let mut bg = BackgroundLoad::new(0.3, 0.0, 0.25);
        let mut rng = Prng::new(5);
        for _ in 0..10_000 {
            bg.step(0.01, &mut rng);
            if bg.is_bursting() {
                assert!(bg.instant() >= bg.observable());
                return;
            }
        }
        panic!("no burst observed");
    }

    #[test]
    fn utilization_bounded() {
        let mut bg = BackgroundLoad::new(0.9, 0.3, 0.5);
        let mut rng = Prng::new(6);
        for _ in 0..10_000 {
            bg.step(0.01, &mut rng);
            assert!((0.0..=0.95).contains(&bg.instant()));
        }
    }

    #[test]
    fn drift_stays_bounded_and_near_one() {
        let mut d = HiddenDrift::new(0.08);
        let mut rng = Prng::new(7);
        let mut sum = 0.0;
        let n = 50_000;
        for _ in 0..n {
            d.step(0.01, &mut rng);
            let f = d.factor();
            assert!((0.5..2.0).contains(&f));
            sum += f;
        }
        let avg = sum / n as f64;
        assert!((0.85..1.2).contains(&avg), "avg {avg}");
    }

    #[test]
    fn set_mean_snaps_level() {
        let mut bg = BackgroundLoad::new(0.2, 0.02, 0.1);
        bg.set_mean(0.6);
        assert!((bg.observable() - 0.6).abs() < 1e-9);
    }
}
