//! The simulated device: SD855 processors + DVFS + thermal + background
//! dynamics, assembled behind a small API:
//!
//! * [`Device::snapshot`] — what a resource monitor can observe
//!   (frequencies, smoothed utilizations, temperature). Hidden burst/drift
//!   state is *not* included.
//! * [`Device::measure`] — ground-truth cost of executing a placement right
//!   now (includes hidden state + measurement noise): what the executor
//!   records and the profiler learns from.
//! * [`Device::expected_cost`] — noise-free cost at the current hidden
//!   state. Used only by benches as an "oracle profiler" upper bound and by
//!   tests; planning code must go through the profiler.
//! * [`Device::advance`] — progress background processes / governor /
//!   thermal in virtual time.
//!
//! Energy accounting: dynamic (switching) energy is attributed per op;
//! static/leakage power is a device-level term (`static_power_w`) that the
//! metrics layer multiplies by wall time — standard practice for
//! energy-per-inference reporting on phones.

use crate::graph::OpNode;
use crate::util::Prng;

use super::background::{BackgroundLoad, HiddenDrift};
use super::governor::{Governor, Thermal};
use super::latency::{
    activity_factor, batch_compute_scale, compute_time, ComputeParams, UnitCondition,
};
use super::opp::OppTable;
use super::power::{batched_activity, PowerParams};
use super::processor::{Placement, Proc};
use super::transfer::{boundary_bytes, TransferParams};

/// Full device parameterization (all constants tunable; defaults = SD855).
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    /// CPU-cluster DVFS operating points.
    pub cpu_opps: OppTable,
    /// GPU DVFS operating points.
    pub gpu_opps: OppTable,
    /// CPU CMOS power parameters.
    pub cpu_power: PowerParams,
    /// GPU CMOS power parameters.
    pub gpu_power: PowerParams,
    /// CPU roofline latency parameters.
    pub cpu_compute: ComputeParams,
    /// GPU roofline latency parameters.
    pub gpu_compute: ComputeParams,
    /// CPU↔GPU shared-memory transfer parameters.
    pub transfer: TransferParams,
    /// Lognormal σ of measurement/execution noise.
    pub noise_sigma: f64,
    /// σ of the hidden drift process (conditions may override).
    pub drift_sigma: f64,
    /// Extra throughput loss per unit of background utilization
    /// (cache/SMT thrashing): eff ×= (1 − thrash · bg).
    pub thrash: f64,
    /// Split-op synchronization overhead (two command queues join), s.
    pub split_sync_s: f64,
    /// Simulator noise seed.
    pub seed: u64,
}

impl DeviceConfig {
    /// The calibrated Snapdragon-855 parameterization (Xiaomi 9 class).
    pub fn snapdragon_855() -> DeviceConfig {
        DeviceConfig {
            cpu_opps: OppTable::sd855_cpu_big(),
            gpu_opps: OppTable::sd855_gpu(),
            cpu_power: PowerParams::sd855_cpu(),
            gpu_power: PowerParams::sd855_gpu(),
            cpu_compute: ComputeParams::sd855_cpu(),
            gpu_compute: ComputeParams::sd855_gpu(),
            transfer: TransferParams::sd855(),
            noise_sigma: 0.04,
            drift_sigma: 0.05,
            thrash: 0.50,
            split_sync_s: 30e-6,
            seed: 0xAD40_0E57,
        }
    }
}

/// A workload condition: pinned frequencies + background-load level.
/// The paper's presets live in [`crate::workload::conditions`].
#[derive(Debug, Clone)]
pub struct ConditionSpec {
    /// Condition name (reports).
    pub name: &'static str,
    /// Pinned CPU frequency (None = governor-controlled).
    pub cpu_freq_hz: Option<f64>,
    /// Pinned GPU frequency (None = governor-controlled).
    pub gpu_freq_hz: Option<f64>,
    /// Mean background CPU utilization.
    pub cpu_bg_mean: f64,
    /// OU sigma of the background CPU load.
    pub cpu_bg_sigma: f64,
    /// CPU burst height (added during burst episodes).
    pub cpu_burst: f64,
    /// Mean background GPU utilization.
    pub gpu_bg_mean: f64,
    /// OU sigma of the background GPU load.
    pub gpu_bg_sigma: f64,
    /// GPU burst height.
    pub gpu_burst: f64,
    /// Ambient DRAM-bandwidth contention factor (0,1].
    pub bw_ambient: f64,
    /// Hidden-drift sigma while this condition holds.
    pub drift_sigma: f64,
}

/// Observable device state (what `/proc`-style monitoring exposes).
#[derive(Debug, Clone, Copy)]
pub struct Snapshot {
    /// Virtual time of the sample.
    pub time_s: f64,
    /// Current CPU-cluster frequency.
    pub cpu_freq_hz: f64,
    /// Current GPU frequency.
    pub gpu_freq_hz: f64,
    /// Smoothed background CPU utilization (burst state invisible).
    pub cpu_util: f64,
    /// Smoothed background GPU utilization.
    pub gpu_util: f64,
    /// Die temperature, °C.
    pub temp_c: f64,
    /// Effective DRAM-bandwidth factor (0,1].
    pub bw_factor: f64,
}

/// Execution context for one op: where its inputs currently live and
/// whether this op starts a new run on each unit (dispatch amortization).
#[derive(Debug, Clone)]
pub struct ExecCtx {
    /// CPU-visible fraction of each input tensor (parallel to op.in_shapes).
    pub input_cpu_fracs: Vec<f64>,
    /// True when the previous op in this unit's queue was not ours
    /// (pay `dispatch_first` instead of `dispatch_next`).
    pub new_run_cpu: bool,
    /// Same as `new_run_cpu`, for the GPU queue.
    pub new_run_gpu: bool,
    /// The *other* unit is concurrently busy with other work (bandwidth
    /// contention from concurrent streams).
    pub concurrent: bool,
}

impl ExecCtx {
    /// Fresh context: inputs fully resident where `prev_cpu_frac` says,
    /// starting new runs on both units.
    pub fn fresh(input_cpu_fracs: Vec<f64>) -> ExecCtx {
        ExecCtx {
            input_cpu_fracs,
            new_run_cpu: true,
            new_run_gpu: true,
            concurrent: false,
        }
    }
}

/// Cost of executing one op under a placement.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpCost {
    /// End-to-end latency contribution (includes transfer + sync), s.
    pub latency_s: f64,
    /// Dynamic energy attributed to the op (compute + transfer), J.
    pub energy_j: f64,
    /// CPU busy seconds (for utilization accounting).
    pub cpu_busy_s: f64,
    /// GPU busy seconds.
    pub gpu_busy_s: f64,
    /// Transfer time included in `latency_s`, s.
    pub transfer_s: f64,
    /// Transfer energy included in `energy_j`, J.
    pub transfer_j: f64,
}

impl OpCost {
    /// Energy-delay product (the AdaOper DP's default objective).
    pub fn edp(&self) -> f64 {
        self.latency_s * self.energy_j
    }
}

/// The simulated Snapdragon-855 device.
pub struct Device {
    /// The parameterization the device was built with.
    pub cfg: DeviceConfig,
    cpu_gov: Governor,
    gpu_gov: Governor,
    thermal: Thermal,
    cpu_bg: BackgroundLoad,
    gpu_bg: BackgroundLoad,
    drift: HiddenDrift,
    bw_ambient: f64,
    rng: Prng,
    time_s: f64,
    condition_name: &'static str,
}

impl Device {
    /// Build a device in the idle condition at time 0.
    pub fn new(cfg: DeviceConfig) -> Device {
        let rng = Prng::new(cfg.seed);
        Device {
            cpu_gov: Governor::new(cfg.cpu_opps.clone()),
            gpu_gov: Governor::new(cfg.gpu_opps.clone()),
            thermal: Thermal::sd855(),
            cpu_bg: BackgroundLoad::idle(),
            gpu_bg: BackgroundLoad::idle(),
            drift: HiddenDrift::new(cfg.drift_sigma),
            bw_ambient: 1.0,
            rng,
            time_s: 0.0,
            condition_name: "idle",
            cfg,
        }
    }

    /// Apply a workload condition (pin frequencies, set background means).
    pub fn apply_condition(&mut self, c: &ConditionSpec) {
        match c.cpu_freq_hz {
            Some(f) => self.cpu_gov.pin(f),
            None => self.cpu_gov.unpin(),
        }
        match c.gpu_freq_hz {
            Some(f) => self.gpu_gov.pin(f),
            None => self.gpu_gov.unpin(),
        }
        self.cpu_bg = BackgroundLoad::new(c.cpu_bg_mean, c.cpu_bg_sigma, c.cpu_burst);
        self.gpu_bg = BackgroundLoad::new(c.gpu_bg_mean, c.gpu_bg_sigma, c.gpu_burst);
        self.bw_ambient = c.bw_ambient;
        self.drift = HiddenDrift::new(c.drift_sigma);
        self.condition_name = c.name;
    }

    /// Name of the currently applied workload condition.
    pub fn condition_name(&self) -> &'static str {
        self.condition_name
    }

    /// Current virtual time, seconds.
    pub fn time_s(&self) -> f64 {
        self.time_s
    }

    /// Observable state.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            time_s: self.time_s,
            cpu_freq_hz: self.cpu_gov.freq_hz(),
            gpu_freq_hz: self.gpu_gov.freq_hz(),
            cpu_util: self.cpu_bg.observable(),
            gpu_util: self.gpu_bg.observable(),
            temp_c: self.thermal.temp_c(),
            bw_factor: self.bw_ambient,
        }
    }

    /// Static (leakage) power of both units, W — metrics multiply by wall
    /// time for total-energy reporting.
    pub fn static_power_w(&self) -> f64 {
        self.cfg.cpu_power.p_static + self.cfg.gpu_power.p_static
    }

    /// Advance virtual time: background, drift, governor, thermal.
    /// `task_util` = fraction of the elapsed interval each unit spent on
    /// foreground (our) work — the governor responds to total utilization.
    pub fn advance(&mut self, dt: f64, task_util_cpu: f64, task_util_gpu: f64) {
        if dt <= 0.0 {
            return;
        }
        self.time_s += dt;
        self.cpu_bg.step(dt, &mut self.rng);
        self.gpu_bg.step(dt, &mut self.rng);
        self.drift.step(dt, &mut self.rng);
        let cpu_total = (self.cpu_bg.instant() + task_util_cpu).min(1.0);
        let gpu_total = (self.gpu_bg.instant() + task_util_gpu).min(1.0);
        let n_cpu = self.cpu_gov.table().points.len();
        let n_gpu = self.gpu_gov.table().points.len();
        self.cpu_gov.step(cpu_total, self.thermal.cap_idx(n_cpu));
        self.gpu_gov.step(gpu_total, self.thermal.cap_idx(n_gpu));
        // Rough instantaneous power for thermal: static + dynamic scaled
        // by utilization.
        let p = self.cfg.cpu_power.total(self.cpu_gov.opp(), cpu_total)
            + self.cfg.gpu_power.total(self.gpu_gov.opp(), gpu_total);
        self.thermal.step(dt, p);
    }

    fn unit_condition(&self, p: Proc, ctx: &ExecCtx, split: bool) -> UnitCondition {
        let (freq, bg) = match p {
            Proc::Cpu => (self.cpu_gov.freq_hz(), self.cpu_bg.instant()),
            Proc::Gpu => (self.gpu_gov.freq_hz(), self.gpu_bg.instant()),
        };
        // Bandwidth: ambient contention × concurrent-stream sharing ×
        // split co-execution sharing.
        let mut bw = self.bw_ambient;
        if ctx.concurrent {
            bw *= 0.85;
        }
        if split {
            bw *= 0.78;
        }
        // thrash: background work degrades effective throughput beyond
        // its cycle share.
        let bg_eff = (bg + self.cfg.thrash * bg * (1.0 - bg)).min(0.97);
        UnitCondition {
            freq_hz: freq,
            bg_util: bg_eff,
            bw_factor: bw,
        }
    }

    /// Noise-free expected cost at the **current hidden state** — the
    /// simulator's ground truth "right now". Planning code must use the
    /// profiler instead; benches use this as the oracle upper bound.
    pub fn expected_cost(&self, op: &OpNode, placement: Placement, ctx: &ExecCtx) -> OpCost {
        // the batch generalization at batch = 1: every batch term is an
        // exact identity there (scale 1.0, activity untouched, bytes × 1),
        // so this is bit-identical to the historical single-request body
        self.expected_cost_batch(op, placement, ctx, 1)
    }

    /// Noise-free expected cost of executing one operator for a *batch* of
    /// `batch` co-dispatched requests in a single dispatch. Transfer moves
    /// every member's activations (bytes × batch); per-unit compute grows
    /// sub-linearly ([`super::latency::batch_compute_scale`]) while the
    /// dispatch overhead is paid **once** per batch — the fixed-cost
    /// amortization the batching subsystem exists for; switching activity
    /// rises with batch depth ([`super::power::batched_activity`]). At
    /// `batch <= 1` every batch term is an exact identity, so this *is*
    /// [`Device::expected_cost`], bit for bit.
    pub fn expected_cost_batch(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        batch: usize,
    ) -> OpCost {
        assert!(placement.is_valid(), "invalid placement {placement:?}");
        let drift = self.drift.factor();

        // --- transfer: every member moves its own mismatched input bytes
        let need_cpu = placement.frac_on(Proc::Cpu);
        let mut transfer_s = 0.0;
        let mut transfer_j = 0.0;
        for (shape, &have_cpu) in op.in_shapes.iter().zip(&ctx.input_cpu_fracs) {
            let bytes = boundary_bytes(shape.bytes(), have_cpu, need_cpu) * batch as u64;
            transfer_s += self.cfg.transfer.time(bytes);
            transfer_j += self.cfg.transfer.energy(bytes);
        }

        // --- compute per unit: sub-linear growth, dispatch paid once
        let split = matches!(placement, Placement::Split { .. });
        let mut cpu_busy = 0.0;
        let mut gpu_busy = 0.0;
        let mut energy = transfer_j;

        for p in Proc::ALL {
            let frac = placement.frac_on(p);
            if frac == 0.0 {
                continue;
            }
            let cond = self.unit_condition(p, ctx, split);
            let (params, power, gov, bg) = match p {
                Proc::Cpu => (
                    &self.cfg.cpu_compute,
                    &self.cfg.cpu_power,
                    &self.cpu_gov,
                    self.cpu_bg.instant(),
                ),
                Proc::Gpu => (
                    &self.cfg.gpu_compute,
                    &self.cfg.gpu_power,
                    &self.gpu_gov,
                    self.gpu_bg.instant(),
                ),
            };
            let dispatch = match p {
                Proc::Cpu if ctx.new_run_cpu => params.dispatch_first,
                Proc::Cpu => params.dispatch_next,
                Proc::Gpu if ctx.new_run_gpu => params.dispatch_first,
                Proc::Gpu => params.dispatch_next,
            };
            let scale = batch_compute_scale(p, batch);
            let t = compute_time(op, p, params, cond, frac) * scale * drift + dispatch;
            let share = (1.0 - bg).max(0.05);
            let act = batched_activity(activity_factor(op, p) * share, batch);
            energy += power.dynamic(gov.opp(), act) * t * drift.sqrt();
            match p {
                Proc::Cpu => cpu_busy = t,
                Proc::Gpu => gpu_busy = t,
            }
        }

        let sync = if split { self.cfg.split_sync_s } else { 0.0 };
        let latency = transfer_s + cpu_busy.max(gpu_busy) + sync;
        OpCost {
            latency_s: latency,
            energy_j: energy,
            cpu_busy_s: cpu_busy,
            gpu_busy_s: gpu_busy,
            transfer_s,
            transfer_j,
        }
    }

    /// Ground-truth *measured* cost: expected cost at the hidden state plus
    /// lognormal measurement noise. This is what execution observes and
    /// what the profiler trains/corrects on.
    pub fn measure(&mut self, op: &OpNode, placement: Placement, ctx: &ExecCtx) -> OpCost {
        self.measure_batch(op, placement, ctx, 1)
    }

    /// [`Device::measure`] for a batched dispatch: the batched expected
    /// cost plus the same lognormal measurement noise (two normal draws,
    /// exactly like the unbatched path, so replacing single dispatches with
    /// batches perturbs no other stream of simulator randomness). At
    /// `batch <= 1` this *is* [`Device::measure`], bit for bit.
    pub fn measure_batch(
        &mut self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        batch: usize,
    ) -> OpCost {
        let mut c = self.expected_cost_batch(op, placement, ctx, batch);
        let s = self.cfg.noise_sigma;
        let nl = (self.rng.normal() * s).exp();
        let ne = (self.rng.normal() * s).exp();
        c.latency_s *= nl;
        c.cpu_busy_s *= nl;
        c.gpu_busy_s *= nl;
        c.energy_j *= ne;
        c
    }

    /// Measured average CPU utilization (background + a given foreground
    /// busy fraction) — lets benches report the paper's "average CPU
    /// utilization" figure.
    pub fn avg_cpu_util(&self, task_busy_frac: f64) -> f64 {
        (self.cpu_bg.observable() + task_busy_frac * (1.0 - self.cpu_bg.observable()))
            .min(1.0)
    }

    /// Direct access to the current hidden drift factor — test/bench
    /// introspection only (not part of the observable API).
    #[doc(hidden)]
    pub fn debug_drift_factor(&self) -> f64 {
        self.drift.factor()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    fn dev() -> Device {
        Device::new(DeviceConfig::snapdragon_855())
    }

    fn moderate() -> ConditionSpec {
        ConditionSpec {
            name: "moderate",
            cpu_freq_hz: Some(1.49e9),
            gpu_freq_hz: Some(499e6),
            cpu_bg_mean: 0.35,
            cpu_bg_sigma: 0.03,
            cpu_burst: 0.10,
            gpu_bg_mean: 0.08,
            gpu_bg_sigma: 0.02,
            gpu_burst: 0.05,
            bw_ambient: 0.92,
            drift_sigma: 0.05,
        }
    }

    fn ctx1() -> ExecCtx {
        ExecCtx::fresh(vec![1.0])
    }

    #[test]
    fn condition_pins_frequencies() {
        let mut d = dev();
        d.apply_condition(&moderate());
        let s = d.snapshot();
        assert!((s.cpu_freq_hz - 1.497e9).abs() < 10e6);
        assert!((s.gpu_freq_hz - 499e6).abs() < 1e6);
    }

    #[test]
    fn gpu_faster_and_cheaper_on_heavy_conv() {
        let mut d = dev();
        d.apply_condition(&moderate());
        let g = zoo::yolov2();
        let op = &g.ops[2]; // conv2 — heavy 3×3
        let cpu = d.expected_cost(op, Placement::CPU, &ctx1());
        let mut c = ctx1();
        c.input_cpu_fracs = vec![0.0];
        let gpu = d.expected_cost(op, Placement::GPU, &c);
        assert!(gpu.latency_s < cpu.latency_s, "gpu {gpu:?} cpu {cpu:?}");
        assert!(gpu.energy_j < cpu.energy_j, "gpu {gpu:?} cpu {cpu:?}");
    }

    #[test]
    fn transfer_cost_applies_on_placement_change() {
        let mut d = dev();
        d.apply_condition(&moderate());
        let g = zoo::yolov2();
        let op = &g.ops[2];
        // input on GPU, run on CPU → pay transfer
        let mut c = ctx1();
        c.input_cpu_fracs = vec![0.0];
        let cross = d.expected_cost(op, Placement::CPU, &c);
        let local = d.expected_cost(op, Placement::CPU, &ctx1());
        assert!(cross.latency_s > local.latency_s);
        assert!(cross.transfer_s > 0.0 && local.transfer_s == 0.0);
        assert!(cross.energy_j > local.energy_j);
    }

    #[test]
    fn split_balances_latency() {
        let mut d = dev();
        d.apply_condition(&moderate());
        let g = zoo::yolov2();
        let op = &g.ops[14]; // conv9 512@26 — big
        let mut c = ctx1();
        c.input_cpu_fracs = vec![0.0];
        let gpu = d.expected_cost(op, Placement::GPU, &c);
        // a near-balanced split should beat pure GPU on latency
        let mut best = f64::INFINITY;
        for r in [0.05, 0.08, 0.10, 0.13, 0.16] {
            let mut cc = c.clone();
            cc.input_cpu_fracs = vec![r];
            let s = d.expected_cost(op, Placement::Split { cpu_frac: r }, &cc);
            best = best.min(s.latency_s);
        }
        assert!(best < gpu.latency_s, "split best {best} gpu {}", gpu.latency_s);
    }

    #[test]
    fn split_costs_more_energy_than_gpu() {
        let mut d = dev();
        d.apply_condition(&moderate());
        let g = zoo::yolov2();
        let op = &g.ops[14];
        let mut c = ctx1();
        c.input_cpu_fracs = vec![0.0];
        let gpu = d.expected_cost(op, Placement::GPU, &c);
        let mut cc = c.clone();
        cc.input_cpu_fracs = vec![0.1];
        let split = d.expected_cost(op, Placement::Split { cpu_frac: 0.1 }, &cc);
        assert!(split.energy_j > gpu.energy_j);
    }

    #[test]
    fn measure_is_noisy_but_unbiased_ish() {
        let mut d = dev();
        d.apply_condition(&moderate());
        let g = zoo::yolov2();
        let op = &g.ops[2];
        let expect = d.expected_cost(op, Placement::GPU, &ctx1());
        let n = 300;
        let mean: f64 = (0..n)
            .map(|_| d.measure(op, Placement::GPU, &ctx1()).latency_s)
            .sum::<f64>()
            / n as f64;
        assert!((mean / expect.latency_s - 1.0).abs() < 0.05);
    }

    #[test]
    fn advance_moves_time_and_keeps_util_near_mean() {
        let mut d = dev();
        d.apply_condition(&moderate());
        for _ in 0..1000 {
            d.advance(0.01, 0.3, 0.5);
        }
        assert!((d.time_s() - 10.0).abs() < 1e-9);
        let s = d.snapshot();
        assert!((s.cpu_util - 0.35).abs() < 0.15, "cpu_util {}", s.cpu_util);
    }

    #[test]
    fn drift_changes_costs_over_time() {
        let mut d = dev();
        let mut spec = moderate();
        spec.drift_sigma = 0.2;
        d.apply_condition(&spec);
        let g = zoo::yolov2();
        let op = &g.ops[2];
        let c0 = d.expected_cost(op, Placement::GPU, &ctx1()).latency_s;
        let mut max_dev: f64 = 0.0;
        for _ in 0..500 {
            d.advance(0.05, 0.0, 0.0);
            let c = d.expected_cost(op, Placement::GPU, &ctx1()).latency_s;
            max_dev = max_dev.max((c / c0 - 1.0).abs());
        }
        assert!(max_dev > 0.05, "drift never moved costs ({max_dev})");
    }

    #[test]
    fn batch_of_one_is_bitwise_identical_to_unbatched() {
        let mut d = dev();
        d.apply_condition(&moderate());
        let g = zoo::yolov2();
        let op = &g.ops[2];
        let a = d.expected_cost(op, Placement::GPU, &ctx1());
        let b = d.expected_cost_batch(op, Placement::GPU, &ctx1(), 1);
        assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits());
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        // measure consumes the same two noise draws either way
        let mut d1 = dev();
        let mut d2 = dev();
        d1.apply_condition(&moderate());
        d2.apply_condition(&moderate());
        let m1 = d1.measure(op, Placement::GPU, &ctx1());
        let m2 = d2.measure_batch(op, Placement::GPU, &ctx1(), 1);
        assert_eq!(m1.latency_s.to_bits(), m2.latency_s.to_bits());
        assert_eq!(m1.energy_j.to_bits(), m2.energy_j.to_bits());
    }

    #[test]
    fn batched_dispatch_amortizes_per_request_cost() {
        let mut d = dev();
        d.apply_condition(&moderate());
        let g = zoo::yolov2_tiny();
        let op = &g.ops[2];
        let mut c = ctx1();
        c.input_cpu_fracs = vec![0.0];
        let single = d.expected_cost_batch(op, Placement::GPU, &c, 1);
        let batch4 = d.expected_cost_batch(op, Placement::GPU, &c, 4);
        // a batch of 4 runs longer than one request but far shorter than 4
        assert!(batch4.latency_s > single.latency_s);
        assert!(batch4.latency_s < 4.0 * single.latency_s);
        // per-request energy falls: fixed costs amortize, compute sub-linear
        assert!(
            batch4.energy_j / 4.0 < single.energy_j,
            "per-req {} !< {}",
            batch4.energy_j / 4.0,
            single.energy_j
        );
    }

    #[test]
    fn dispatch_amortization_rewards_runs() {
        let mut d = dev();
        d.apply_condition(&moderate());
        let g = zoo::yolov2();
        let op = &g.ops[25]; // small-ish op so dispatch matters
        let mut first = ctx1();
        first.input_cpu_fracs = vec![0.0];
        let mut next = first.clone();
        next.new_run_gpu = false;
        let a = d.expected_cost(op, Placement::GPU, &first);
        let b = d.expected_cost(op, Placement::GPU, &next);
        assert!(a.latency_s > b.latency_s);
    }

    #[test]
    fn high_condition_slows_cpu_more() {
        let g = zoo::yolov2();
        let op = &g.ops[2];
        let mut d1 = dev();
        d1.apply_condition(&moderate());
        let mod_cpu = d1.expected_cost(op, Placement::CPU, &ctx1()).latency_s;
        let mut d2 = dev();
        let high = ConditionSpec {
            name: "high",
            cpu_freq_hz: Some(0.88e9),
            gpu_freq_hz: Some(427e6),
            cpu_bg_mean: 0.55,
            cpu_bg_sigma: 0.05,
            cpu_burst: 0.25,
            gpu_bg_mean: 0.12,
            gpu_bg_sigma: 0.03,
            gpu_burst: 0.08,
            bw_ambient: 0.82,
            drift_sigma: 0.10,
        };
        d2.apply_condition(&high);
        let high_cpu = d2.expected_cost(op, Placement::CPU, &ctx1()).latency_s;
        let mod_gpu = {
            let mut c = ctx1();
            c.input_cpu_fracs = vec![0.0];
            d1.expected_cost(op, Placement::GPU, &c).latency_s
        };
        let high_gpu = {
            let mut c = ctx1();
            c.input_cpu_fracs = vec![0.0];
            d2.expected_cost(op, Placement::GPU, &c).latency_s
        };
        // CPU suffers proportionally more than GPU under the high condition
        assert!(high_cpu / mod_cpu > high_gpu / mod_gpu * 1.3);
    }
}
