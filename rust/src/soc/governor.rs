//! DVFS governor (schedutil-style) and thermal throttling.
//!
//! When a workload *condition* pins frequencies (as the paper's experiments
//! do), the governor is disabled for that unit. In dynamic traces — the
//! profiler-adaptation ablation — the governor walks the OPP table toward
//! `util / target_util`, and the thermal model caps the top OPP as the
//! sustained-power envelope is exceeded.

use crate::util::stats::Ewma;

use super::opp::OppTable;

/// Per-unit governor state.
#[derive(Debug, Clone)]
pub struct Governor {
    table: OppTable,
    /// Current OPP index.
    idx: usize,
    /// Pinned (condition-fixed) OPP index, if any.
    pinned: Option<usize>,
    /// schedutil target utilization.
    target_util: f64,
    util_ewma: Ewma,
}

impl Governor {
    /// Build at the table's top OPP, unpinned.
    pub fn new(table: OppTable) -> Self {
        let idx = table.points.len() - 1;
        Governor {
            table,
            idx,
            pinned: None,
            target_util: 0.8,
            util_ewma: Ewma::new(0.3),
        }
    }

    /// Pin to the OPP nearest `freq_hz` (workload-condition presets).
    pub fn pin(&mut self, freq_hz: f64) {
        let i = self.table.nearest_idx(freq_hz);
        self.pinned = Some(i);
        self.idx = i;
    }

    /// Release the pin (dynamic governor resumes).
    pub fn unpin(&mut self) {
        self.pinned = None;
    }

    /// Current frequency.
    pub fn freq_hz(&self) -> f64 {
        self.table.points[self.idx].freq_hz
    }

    /// Current voltage.
    pub fn volt(&self) -> f64 {
        self.table.points[self.idx].volt
    }

    /// Current operating point.
    pub fn opp(&self) -> super::opp::Opp {
        self.table.points[self.idx]
    }

    /// One governor tick: adjust frequency toward the observed utilization
    /// (`util` = fraction busy over the last interval), bounded by the
    /// thermal cap index.
    pub fn step(&mut self, util: f64, thermal_cap_idx: usize) {
        if let Some(p) = self.pinned {
            // Thermal still applies to pinned units (phones do throttle
            // pinned governors), but condition experiments set caps high.
            self.idx = p.min(thermal_cap_idx);
            return;
        }
        let u = self.util_ewma.push(util.clamp(0.0, 1.0));
        // schedutil: f_next = 1.25 · f_cur · u / target
        let f_want = 1.25 * self.freq_hz() * u / self.target_util;
        let mut want_idx = self.table.nearest_idx(f_want);
        // move at most 2 steps per tick (rate limiting)
        let cur = self.idx as isize;
        let delta = (want_idx as isize - cur).clamp(-2, 2);
        want_idx = self.table.clamp_idx(cur + delta);
        self.idx = want_idx.min(thermal_cap_idx);
    }

    /// The DVFS table driven by this governor.
    pub fn table(&self) -> &OppTable {
        &self.table
    }
}

/// Lumped-thermal model: junction temperature follows power with a first-
/// order RC; above `throttle_start` the allowed top OPP index ramps down.
#[derive(Debug, Clone)]
pub struct Thermal {
    /// Temperature rise per watt at equilibrium (K/W).
    pub r_th: f64,
    /// Time constant (s).
    pub tau: f64,
    /// Ambient/skin-coupled baseline, °C.
    pub ambient: f64,
    /// Throttling begins here, °C.
    pub throttle_start: f64,
    /// Full throttle (min OPP) here, °C.
    pub throttle_end: f64,
    temp: f64,
}

impl Thermal {
    /// SD855-class thermal constants.
    pub fn sd855() -> Thermal {
        Thermal {
            r_th: 7.0,
            tau: 18.0,
            ambient: 30.0,
            throttle_start: 62.0,
            throttle_end: 80.0,
            temp: 30.0,
        }
    }

    /// Advance by `dt` with total SoC power `power_w`.
    pub fn step(&mut self, dt: f64, power_w: f64) {
        let target = self.ambient + self.r_th * power_w;
        let a = 1.0 - (-dt / self.tau).exp();
        self.temp += (target - self.temp) * a;
    }

    /// Current junction temperature, °C.
    pub fn temp_c(&self) -> f64 {
        self.temp
    }

    /// Top allowed OPP index for a table of `n` OPPs.
    pub fn cap_idx(&self, n: usize) -> usize {
        if self.temp <= self.throttle_start {
            return n - 1;
        }
        if self.temp >= self.throttle_end {
            return 0;
        }
        let x = (self.temp - self.throttle_start) / (self.throttle_end - self.throttle_start);
        (((n - 1) as f64) * (1.0 - x)).round() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::opp::OppTable;

    #[test]
    fn pin_fixes_frequency() {
        let mut g = Governor::new(OppTable::sd855_cpu_big());
        g.pin(1.49e9);
        for _ in 0..50 {
            g.step(1.0, usize::MAX);
        }
        assert!((g.freq_hz() - 1.497e9).abs() < 10e6);
    }

    #[test]
    fn governor_ramps_up_under_load() {
        let mut g = Governor::new(OppTable::sd855_cpu_big());
        g.idx = 0; // start at min
        let n = g.table.points.len();
        for _ in 0..50 {
            g.step(1.0, n - 1);
        }
        assert_eq!(g.freq_hz(), g.table.max().freq_hz);
    }

    #[test]
    fn governor_settles_down_when_idle() {
        let mut g = Governor::new(OppTable::sd855_cpu_big());
        let n = g.table.points.len();
        for _ in 0..100 {
            g.step(0.05, n - 1);
        }
        assert!(g.freq_hz() <= g.table.points[2].freq_hz);
    }

    #[test]
    fn thermal_heats_and_caps() {
        let mut th = Thermal::sd855();
        let n = 18;
        assert_eq!(th.cap_idx(n), n - 1);
        for _ in 0..600 {
            th.step(0.1, 6.0); // 6 W sustained → 72 °C equilibrium
        }
        assert!(th.temp_c() > 62.0, "temp {}", th.temp_c());
        assert!(th.cap_idx(n) < n - 1);
    }

    #[test]
    fn thermal_cools_back() {
        let mut th = Thermal::sd855();
        for _ in 0..600 {
            th.step(0.1, 6.0);
        }
        let hot = th.temp_c();
        for _ in 0..1200 {
            th.step(0.1, 0.3);
        }
        assert!(th.temp_c() < hot - 10.0);
    }

    #[test]
    fn thermal_cap_monotone_in_temp() {
        let mut th = Thermal::sd855();
        th.temp = 65.0;
        let a = th.cap_idx(18);
        th.temp = 75.0;
        let b = th.cap_idx(18);
        assert!(b < a);
    }
}
