//! Roofline operator latency model.
//!
//! `t_op = max(flops / eff_throughput, bytes / eff_bandwidth) + dispatch`,
//! where effective throughput depends on the operating point, the kind of
//! operator (conv maps well to both units, depthwise conv poorly to the
//! GPU, elementwise ops are bandwidth-bound everywhere) and how much of the
//! unit background work has stolen. GPU work additionally pays a per-run
//! command-queue dispatch overhead — the term that makes fine-grained
//! CPU↔GPU ping-ponging expensive and op-grouping (CoDL) worthwhile.

use crate::graph::OpNode;

use super::processor::Proc;

/// Per-processor compute/bandwidth capability at a fixed frequency.
#[derive(Debug, Clone, Copy)]
pub struct ComputeParams {
    /// Peak FLOP per cycle across the unit (all cores / ALUs).
    pub flops_per_cycle: f64,
    /// Effective DRAM bandwidth the unit can pull alone, bytes/s.
    pub mem_bw: f64,
    /// Dispatch overhead for the *first* op of a run on this unit, s.
    pub dispatch_first: f64,
    /// Dispatch overhead for subsequent ops in the same run, s.
    pub dispatch_next: f64,
}

impl ComputeParams {
    /// Kryo-485 big cluster: 4 cores × 2×128-bit NEON FMA pipes
    /// → 4 × 16 = 64 FLOP/cycle. ~14 GB/s streaming alone.
    pub fn sd855_cpu() -> ComputeParams {
        ComputeParams {
            flops_per_cycle: 64.0,
            mem_bw: 14.0e9,
            dispatch_first: 25e-6,
            dispatch_next: 8e-6,
        }
    }

    /// Adreno 640: 2 SPs × 2 uSPs × 64 ALUs × 2 (FMA) ≈ 1536 FLOP/cycle
    /// (954 GFLOPS at 585 MHz wave-peak, ~60% of the marketing number is
    /// reachable for GEMM-like work — folded into `efficiency`).
    /// ~22 GB/s streaming alone; OpenCL enqueue+flush ≈ 110 µs.
    pub fn sd855_gpu() -> ComputeParams {
        ComputeParams {
            flops_per_cycle: 1536.0,
            mem_bw: 22.0e9,
            dispatch_first: 110e-6,
            dispatch_next: 18e-6,
        }
    }

    /// The SD855 parameters for `p`.
    pub fn for_proc(p: Proc) -> ComputeParams {
        match p {
            Proc::Cpu => ComputeParams::sd855_cpu(),
            Proc::Gpu => ComputeParams::sd855_gpu(),
        }
    }
}

/// Fraction of peak FLOP/cycle an operator kind actually achieves on a
/// unit (kernel quality / shape effects, folded constants).
pub fn efficiency(op: &OpNode, proc: Proc) -> f64 {
    let k = op.kind.label();
    match (k, proc) {
        // dense conv: NEON/winograd kernels do well; Adreno fp32 conv
        // utilization is notoriously modest (~0.3 of wave peak)
        ("conv", Proc::Cpu) => 0.60,
        ("conv", Proc::Gpu) => 0.28,
        // 1×1 conv = GEMM, slightly lower arithmetic intensity
        ("conv1x1", Proc::Cpu) => 0.55,
        ("conv1x1", Proc::Gpu) => 0.26,
        // depthwise: bandwidth-starved on GPU (CoDL's observation)
        ("dwconv", Proc::Cpu) => 0.30,
        ("dwconv", Proc::Gpu) => 0.10,
        ("fc", Proc::Cpu) => 0.40,
        ("fc", Proc::Gpu) => 0.30,
        // everything else is effectively bandwidth-bound; the FLOP term
        // rarely dominates, but keep sane values
        (_, Proc::Cpu) => 0.25,
        (_, Proc::Gpu) => 0.20,
    }
}

/// Inputs describing the unit's instantaneous condition.
#[derive(Debug, Clone, Copy)]
pub struct UnitCondition {
    /// Current clock frequency, Hz.
    pub freq_hz: f64,
    /// Fraction of the unit's capacity stolen by background work, [0,1).
    pub bg_util: f64,
    /// Bandwidth contention factor, (0,1]: 1 = alone, <1 = sharing DRAM.
    pub bw_factor: f64,
}

/// Compute time (seconds, no dispatch) for `frac` of an op on a unit.
pub fn compute_time(
    op: &OpNode,
    proc: Proc,
    params: &ComputeParams,
    cond: UnitCondition,
    frac: f64,
) -> f64 {
    debug_assert!((0.0..=1.0).contains(&frac));
    if frac == 0.0 {
        return 0.0;
    }
    let avail = (1.0 - cond.bg_util).max(0.02);
    let eff_flops = params.flops_per_cycle * cond.freq_hz * efficiency(op, proc) * avail;
    let eff_bw = params.mem_bw * cond.bw_factor * avail.max(0.3); // bw less sensitive to cpu load
    let t_compute = op.flops as f64 * frac / eff_flops;
    let t_mem = op.activation_bytes as f64 * frac / eff_bw;
    t_compute.max(t_mem)
}

/// Per-processor batch-scaling parameters for co-dispatched request
/// batches (see `crate::batching`): executing the same operator for `B`
/// requests in one dispatch grows compute time as `B^alpha` (sub-linear —
/// weight reuse and fuller pipelines amortize per-request overheads) until
/// the `knee`, past which every extra request adds `overload` of relative
/// slowdown (working sets spill the caches and the units saturate DRAM).
/// Dispatch overhead is *not* scaled: a batch pays it once, which is the
/// fixed-cost amortization batching exists for.
#[derive(Debug, Clone, Copy)]
pub struct BatchScaling {
    /// Sub-linear compute-growth exponent (`t_B = t_1 · B^alpha`).
    pub alpha: f64,
    /// Batch size past which per-request efficiency stops improving.
    pub knee: usize,
    /// Relative slowdown per request beyond the knee.
    pub overload: f64,
}

impl BatchScaling {
    /// The unit's batch-scaling parameters. The GPU batches well (deep
    /// pipelines, weight reuse across the batch); the CPU is near-linear
    /// (NEON lanes are already saturated by a single request) and its
    /// caches spill earlier.
    pub fn for_proc(p: Proc) -> BatchScaling {
        match p {
            Proc::Cpu => BatchScaling {
                alpha: 0.96,
                knee: 4,
                overload: 0.06,
            },
            Proc::Gpu => BatchScaling {
                alpha: 0.72,
                knee: 8,
                overload: 0.04,
            },
        }
    }
}

/// Multiplier on single-request *compute* time for a batch of `batch`
/// requests on `proc` (dispatch overhead excluded — it is paid once per
/// batch). `1.0` exactly for `batch <= 1`; strictly increasing in the
/// batch size.
pub fn batch_compute_scale(proc: Proc, batch: usize) -> f64 {
    if batch <= 1 {
        return 1.0;
    }
    let s = BatchScaling::for_proc(proc);
    let base = (batch as f64).powf(s.alpha);
    let over = batch.saturating_sub(s.knee) as f64;
    base * (1.0 + s.overload * over)
}

/// The activity factor to feed the power model for this op: compute-bound
/// ops switch the whole datapath; memory-bound ops keep ALUs half idle.
pub fn activity_factor(op: &OpNode, proc: Proc) -> f64 {
    match (op.kind.label(), proc) {
        ("conv" | "conv1x1" | "fc", _) => 1.0,
        ("dwconv", Proc::Cpu) => 0.8,
        ("dwconv", Proc::Gpu) => 0.6,
        _ => 0.5,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    fn nominal(p: Proc) -> UnitCondition {
        UnitCondition {
            freq_hz: match p {
                Proc::Cpu => 2.419e9,
                Proc::Gpu => 585e6,
            },
            bg_util: 0.0,
            bw_factor: 1.0,
        }
    }

    #[test]
    fn yolov2_gpu_latency_plausible() {
        // Sum of pure compute times @ 585 MHz should land near published
        // mobile-GPU YOLOv2 latencies (~60–150 ms on Adreno 640 class).
        let g = zoo::yolov2();
        let params = ComputeParams::sd855_gpu();
        let t: f64 = g
            .ops
            .iter()
            .map(|o| compute_time(o, Proc::Gpu, &params, nominal(Proc::Gpu), 1.0))
            .sum();
        assert!((0.04..0.20).contains(&t), "gpu yolov2 {t} s");
    }

    #[test]
    fn yolov2_cpu_slower_than_gpu() {
        let g = zoo::yolov2();
        let cpu: f64 = g
            .ops
            .iter()
            .map(|o| {
                compute_time(o, Proc::Cpu, &ComputeParams::sd855_cpu(), nominal(Proc::Cpu), 1.0)
            })
            .sum();
        let gpu: f64 = g
            .ops
            .iter()
            .map(|o| {
                compute_time(o, Proc::Gpu, &ComputeParams::sd855_gpu(), nominal(Proc::Gpu), 1.0)
            })
            .sum();
        assert!(cpu > 2.0 * gpu, "cpu {cpu} vs gpu {gpu}");
        assert!(cpu < 20.0 * gpu, "cpu {cpu} vs gpu {gpu}");
    }

    #[test]
    fn depthwise_relatively_better_on_cpu() {
        let g = zoo::mobilenet_v1();
        let dw = g.ops.iter().find(|o| o.kind.label() == "dwconv").unwrap();
        let pw = g.ops.iter().find(|o| o.kind.label() == "conv1x1").unwrap();
        let c = |op, p: Proc| {
            compute_time(op, p, &ComputeParams::for_proc(p), nominal(p), 1.0)
        };
        // GPU speedup on pointwise conv must exceed its speedup on dwconv
        let speedup_pw = c(pw, Proc::Cpu) / c(pw, Proc::Gpu);
        let speedup_dw = c(dw, Proc::Cpu) / c(dw, Proc::Gpu);
        assert!(speedup_pw > speedup_dw);
    }

    #[test]
    fn background_load_slows_cpu() {
        let g = zoo::yolov2();
        let op = &g.ops[0];
        let params = ComputeParams::sd855_cpu();
        let idle = compute_time(op, Proc::Cpu, &params, nominal(Proc::Cpu), 1.0);
        let loaded = compute_time(
            op,
            Proc::Cpu,
            &params,
            UnitCondition {
                bg_util: 0.5,
                ..nominal(Proc::Cpu)
            },
            1.0,
        );
        assert!(loaded > 1.8 * idle);
    }

    #[test]
    fn frequency_scales_compute_bound_latency() {
        let g = zoo::yolov2();
        let op = &g.ops[2]; // conv2: heavy, compute-bound
        let params = ComputeParams::sd855_cpu();
        let fast = compute_time(op, Proc::Cpu, &params, nominal(Proc::Cpu), 1.0);
        let slow = compute_time(
            op,
            Proc::Cpu,
            &params,
            UnitCondition {
                freq_hz: 0.883e9,
                ..nominal(Proc::Cpu)
            },
            1.0,
        );
        let ratio = slow / fast;
        assert!((2.4..3.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn batch_scale_is_identity_at_one_and_monotone() {
        for p in [Proc::Cpu, Proc::Gpu] {
            assert_eq!(batch_compute_scale(p, 0), 1.0);
            assert_eq!(batch_compute_scale(p, 1), 1.0);
            let mut prev = 1.0;
            for b in 2..=32 {
                let s = batch_compute_scale(p, b);
                assert!(s > prev, "{p:?} batch {b}: {s} !> {prev}");
                assert!(s < b as f64 * 1.6, "{p:?} batch {b} scale {s} implausible");
                prev = s;
            }
        }
    }

    #[test]
    fn gpu_amortizes_batches_better_than_cpu() {
        // per-request compute time = scale / B must shrink faster on GPU
        for b in [2usize, 4, 8] {
            let cpu = batch_compute_scale(Proc::Cpu, b) / b as f64;
            let gpu = batch_compute_scale(Proc::Gpu, b) / b as f64;
            assert!(gpu < cpu, "batch {b}: gpu {gpu} !< cpu {cpu}");
        }
    }

    #[test]
    fn zero_frac_costs_nothing() {
        let g = zoo::yolov2();
        assert_eq!(
            compute_time(
                &g.ops[0],
                Proc::Cpu,
                &ComputeParams::sd855_cpu(),
                nominal(Proc::Cpu),
                0.0
            ),
            0.0
        );
    }
}
