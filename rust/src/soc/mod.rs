//! Snapdragon-855 SoC simulator.
//!
//! This module is the substitute for the paper's physical testbed (Xiaomi 9):
//! a calibrated analytical model of a mobile heterogeneous SoC — per-cluster
//! DVFS operating points ([`opp`]), CMOS power ([`power`]), roofline operator
//! latency ([`latency`]), CPU↔GPU shared-memory transfer costs ([`transfer`]),
//! stochastic background workload ([`background`]), a schedutil-style
//! governor with thermal throttling ([`governor`]) — assembled into a
//! [`Device`] that executes operator placements in virtual time and accounts
//! energy ([`device`]).
//!
//! The coordinator treats [`Device`] as ground truth: the profiler *learns*
//! its behaviour from observed (features → energy) pairs, never by peeking
//! at the model internals. A hidden drift process (see [`background`])
//! deliberately breaks any static model, which is what the paper's GRU-based
//! runtime corrector exists to track.

pub mod background;
pub mod device;
pub mod governor;
pub mod latency;
pub mod opp;
pub mod power;
pub mod processor;
pub mod transfer;

pub use device::{Device, DeviceConfig, OpCost, Snapshot};
pub use processor::{Placement, Proc};
