//! DVFS operating-performance-point (OPP) tables for the Snapdragon 855.
//!
//! Frequencies follow the shipped kernel's cpufreq/devfreq tables (subset);
//! voltages are a standard near-linear V(f) fit — the *relative* shape of
//! V(f) is what the energy/frequency tradeoff depends on. The paper pins
//! the CPU to 1.49 GHz / 0.88 GHz and the GPU to 499 / 427 MHz for its two
//! workload conditions; both sit on these tables.

/// One operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Opp {
    /// Clock frequency, Hz.
    pub freq_hz: f64,
    /// Supply voltage, volts.
    pub volt: f64,
}

/// A processor's DVFS table (ascending frequency).
#[derive(Debug, Clone)]
pub struct OppTable {
    /// Operating points, ascending in frequency.
    pub points: Vec<Opp>,
}

impl OppTable {
    /// Build, asserting frequencies ascend and voltage is monotone.
    pub fn new(points: Vec<Opp>) -> Self {
        assert!(!points.is_empty());
        for w in points.windows(2) {
            assert!(w[0].freq_hz < w[1].freq_hz, "OPPs must ascend");
            assert!(w[0].volt <= w[1].volt, "voltage must be monotone");
        }
        OppTable { points }
    }

    /// Kryo-485 gold (big) cluster, 710 MHz – 2.42 GHz.
    /// Voltage ramp 0.57 V → 0.95 V.
    pub fn sd855_cpu_big() -> OppTable {
        let freqs_mhz = [
            710.0, 825.0, 883.0, 940.0, 1056.0, 1171.0, 1286.0, 1401.0, 1497.0, 1612.0,
            1708.0, 1804.0, 1920.0, 2016.0, 2131.0, 2227.0, 2323.0, 2419.0,
        ];
        OppTable::new(
            freqs_mhz
                .iter()
                .map(|&m| Opp {
                    freq_hz: m * 1e6,
                    volt: volt_fit(m * 1e6, 710e6, 2419e6, 0.57, 0.95),
                })
                .collect(),
        )
    }

    /// Adreno-640 GPU, 257 – 675 MHz. Voltage ramp 0.60 V → 0.85 V.
    pub fn sd855_gpu() -> OppTable {
        let freqs_mhz = [257.0, 300.0, 342.0, 414.0, 427.0, 499.0, 585.0, 675.0];
        OppTable::new(
            freqs_mhz
                .iter()
                .map(|&m| Opp {
                    freq_hz: m * 1e6,
                    volt: volt_fit(m * 1e6, 257e6, 675e6, 0.60, 0.85),
                })
                .collect(),
        )
    }

    /// Lowest operating point.
    pub fn min(&self) -> Opp {
        self.points[0]
    }

    /// Highest operating point.
    pub fn max(&self) -> Opp {
        *self.points.last().unwrap()
    }

    /// The table index whose frequency is nearest `freq_hz`.
    pub fn nearest_idx(&self, freq_hz: f64) -> usize {
        let mut best = 0;
        let mut err = f64::INFINITY;
        for (i, p) in self.points.iter().enumerate() {
            let e = (p.freq_hz - freq_hz).abs();
            if e < err {
                err = e;
                best = i;
            }
        }
        best
    }

    /// The OPP nearest `freq_hz` (how conditions pin frequencies).
    pub fn nearest(&self, freq_hz: f64) -> Opp {
        self.points[self.nearest_idx(freq_hz)]
    }

    /// Smallest OPP whose frequency ≥ the requested one (governor step-up
    /// target); saturates at max.
    pub fn at_least(&self, freq_hz: f64) -> Opp {
        for p in &self.points {
            if p.freq_hz >= freq_hz - 1.0 {
                return *p;
            }
        }
        self.max()
    }

    /// Clamp an OPP index into the table.
    pub fn clamp_idx(&self, idx: isize) -> usize {
        idx.clamp(0, self.points.len() as isize - 1) as usize
    }
}

/// Near-linear voltage/frequency fit with a mild superlinear tail (matches
/// the shape of published SD855 rail data).
fn volt_fit(f: f64, f_min: f64, f_max: f64, v_min: f64, v_max: f64) -> f64 {
    let x = ((f - f_min) / (f_max - f_min)).clamp(0.0, 1.0);
    let shaped = 0.8 * x + 0.2 * x * x; // slight curvature upward
    v_min + (v_max - v_min) * shaped
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_table_contains_paper_conditions() {
        let t = OppTable::sd855_cpu_big();
        // paper: 1.49 GHz (moderate), 0.88 GHz (high)
        assert!((t.nearest(1.49e9).freq_hz - 1.497e9).abs() < 10e6);
        assert!((t.nearest(0.88e9).freq_hz - 0.883e9).abs() < 10e6);
    }

    #[test]
    fn gpu_table_contains_paper_conditions() {
        let t = OppTable::sd855_gpu();
        assert_eq!(t.nearest(499e6).freq_hz, 499e6);
        assert_eq!(t.nearest(427e6).freq_hz, 427e6);
    }

    #[test]
    fn voltage_monotone() {
        for t in [OppTable::sd855_cpu_big(), OppTable::sd855_gpu()] {
            for w in t.points.windows(2) {
                assert!(w[1].volt >= w[0].volt);
            }
            assert!(t.min().volt >= 0.5 && t.max().volt <= 1.0);
        }
    }

    #[test]
    fn at_least_steps_up() {
        let t = OppTable::sd855_gpu();
        assert_eq!(t.at_least(450e6).freq_hz, 499e6);
        assert_eq!(t.at_least(10e9).freq_hz, t.max().freq_hz);
        assert_eq!(t.at_least(0.0).freq_hz, t.min().freq_hz);
    }

    #[test]
    fn nearest_idx_endpoints() {
        let t = OppTable::sd855_cpu_big();
        assert_eq!(t.nearest_idx(0.0), 0);
        assert_eq!(t.nearest_idx(1e12), t.points.len() - 1);
    }
}
