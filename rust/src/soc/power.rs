//! CMOS power model: `P = P_static + C_eff · f · V(f)² · activity`.
//!
//! Constants are calibrated so absolute draws land in the published range
//! for an SD855 phone (CPU big cluster ≈ 2.5–3 W flat-out, Adreno 640 ≈
//! 2–2.5 W), but what the experiments depend on is the *ratio* of CPU to
//! GPU energy-per-FLOP and its movement with frequency/utilization — the
//! effect AdaOper exploits.

use super::opp::Opp;
use super::processor::Proc;

/// Per-processor power parameters.
#[derive(Debug, Clone, Copy)]
pub struct PowerParams {
    /// Effective switched capacitance × activity at full load, in
    /// farad-equivalents: `P_dyn = c_eff · f · V²` at activity 1.
    pub c_eff: f64,
    /// Leakage + always-on rail share attributed to the unit, watts.
    pub p_static: f64,
}

impl PowerParams {
    /// Kryo-485 big cluster (sustained NEON conv load ≈ 2.0 W at fmax —
    /// the thermally sustainable envelope, not the instantaneous burst
    /// peak).
    pub fn sd855_cpu() -> PowerParams {
        // 2.0 W ≈ c · 2.419e9 · 0.95² + 0.15  →  c ≈ 0.85e-9
        PowerParams {
            c_eff: 0.85e-9,
            p_static: 0.15,
        }
    }

    /// Adreno 640 (≈ 2.9 W at 585 MHz under full conv load, including the
    /// memory-system draw attributed to the GPU rail).
    pub fn sd855_gpu() -> PowerParams {
        // 2.9 W ≈ c · 585e6 · 0.7934² + 0.10 → c ≈ 7.6e-9
        PowerParams {
            c_eff: 7.6e-9,
            p_static: 0.10,
        }
    }

    /// The SD855 parameters for `p`.
    pub fn for_proc(p: Proc) -> PowerParams {
        match p {
            Proc::Cpu => PowerParams::sd855_cpu(),
            Proc::Gpu => PowerParams::sd855_gpu(),
        }
    }

    /// Dynamic power at an operating point with a given activity factor
    /// (fraction of the unit's pipelines actually switching).
    pub fn dynamic(&self, opp: Opp, activity: f64) -> f64 {
        debug_assert!((0.0..=1.0).contains(&activity));
        self.c_eff * opp.freq_hz * opp.volt * opp.volt * activity
    }

    /// Total power at an operating point and activity.
    pub fn total(&self, opp: Opp, activity: f64) -> f64 {
        self.p_static + self.dynamic(opp, activity)
    }
}

/// Activity factor under a batched dispatch: co-dispatched requests keep
/// the datapath fuller (back-to-back work hides issue bubbles), raising
/// the switching activity logarithmically with the batch size, capped at
/// full activity. Identity for `batch <= 1` (the unbatched path is
/// untouched bit for bit).
///
/// The 3 %-per-`ln B` coefficient is deliberately below the CPU's
/// `1 − alpha` batch-amortization margin
/// ([`super::latency::BatchScaling`], α = 0.96), so per-request energy
/// stays non-increasing up to the amortization knee on *both* units —
/// the invariant the batching property tests pin.
pub fn batched_activity(activity: f64, batch: usize) -> f64 {
    if batch <= 1 {
        return activity;
    }
    (activity * (1.0 + 0.03 * (batch as f64).ln())).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::opp::OppTable;

    #[test]
    fn cpu_peak_power_in_published_range() {
        let t = OppTable::sd855_cpu_big();
        let p = PowerParams::sd855_cpu().total(t.max(), 1.0);
        assert!((1.5..2.5).contains(&p), "cpu peak {p} W");
    }

    #[test]
    fn gpu_peak_power_in_published_range() {
        let t = OppTable::sd855_gpu();
        let opp585 = t.nearest(585e6);
        let p = PowerParams::sd855_gpu().total(opp585, 1.0);
        assert!((2.3..3.3).contains(&p), "gpu peak {p} W");
    }

    #[test]
    fn power_grows_superlinearly_with_frequency() {
        // V rises with f, so P/f must increase with f.
        let t = OppTable::sd855_cpu_big();
        let pp = PowerParams::sd855_cpu();
        let lo = t.nearest(0.883e9);
        let hi = t.nearest(2.419e9);
        let eff_lo = pp.dynamic(lo, 1.0) / lo.freq_hz;
        let eff_hi = pp.dynamic(hi, 1.0) / hi.freq_hz;
        assert!(eff_hi > eff_lo * 1.3, "no superlinear growth");
    }

    #[test]
    fn batched_activity_identity_at_one_and_capped() {
        assert_eq!(batched_activity(0.7, 0), 0.7);
        assert_eq!(batched_activity(0.7, 1), 0.7);
        let a2 = batched_activity(0.7, 2);
        let a8 = batched_activity(0.7, 8);
        assert!(a2 > 0.7 && a8 > a2, "{a2} {a8}");
        assert!(batched_activity(0.99, 64) <= 1.0);
    }

    #[test]
    fn zero_activity_leaves_static_only() {
        let t = OppTable::sd855_gpu();
        let pp = PowerParams::sd855_gpu();
        assert_eq!(pp.total(t.min(), 0.0), pp.p_static);
    }
}
