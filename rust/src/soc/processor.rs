//! Processor identifiers and operator placements.

use std::fmt;

/// A compute unit of the SoC. The paper (and CoDL) co-execute across the
/// CPU big cluster and the GPU; the simulator is written so further units
/// (e.g. an NPU) slot in by extending this enum and the device tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Proc {
    /// Kryo-485 big-core cluster (treated as one schedulable resource, as
    /// MACE/CoDL do with their CPU thread pool).
    Cpu,
    /// Adreno-640 GPU.
    Gpu,
}

impl Proc {
    /// Both units, in index order.
    pub const ALL: [Proc; 2] = [Proc::Cpu, Proc::Gpu];

    /// Dense index (CPU = 0, GPU = 1) for per-proc arrays.
    pub fn index(self) -> usize {
        match self {
            Proc::Cpu => 0,
            Proc::Gpu => 1,
        }
    }

    /// Lowercase name (reports).
    pub fn name(self) -> &'static str {
        match self {
            Proc::Cpu => "cpu",
            Proc::Gpu => "gpu",
        }
    }
}

impl fmt::Display for Proc {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// How a single operator is placed onto processors.
///
/// `Split` is CoDL-style intra-operator co-execution: the output channels
/// (conv) / rows (FC) are divided, `cpu_frac` of the work on the CPU and
/// the rest on the GPU, synchronized at the end of the op.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Placement {
    /// The whole op on one unit.
    Single(Proc),
    Split {
        /// Fraction of the op's work done on the CPU, in (0, 1).
        cpu_frac: f64,
    },
}

impl Placement {
    /// The whole op on the CPU cluster.
    pub const CPU: Placement = Placement::Single(Proc::Cpu);
    /// The whole op on the GPU.
    pub const GPU: Placement = Placement::Single(Proc::Gpu);

    /// Fraction of the op's work executed on `p`.
    pub fn frac_on(&self, p: Proc) -> f64 {
        match *self {
            Placement::Single(q) => {
                if q == p {
                    1.0
                } else {
                    0.0
                }
            }
            Placement::Split { cpu_frac } => match p {
                Proc::Cpu => cpu_frac,
                Proc::Gpu => 1.0 - cpu_frac,
            },
        }
    }

    /// True when any work lands on `p`.
    pub fn uses(&self, p: Proc) -> bool {
        self.frac_on(p) > 0.0
    }

    /// Canonical short label, e.g. `cpu`, `gpu`, `split(0.30)`.
    pub fn label(&self) -> String {
        match *self {
            Placement::Single(p) => p.name().to_string(),
            Placement::Split { cpu_frac } => format!("split({cpu_frac:.2})"),
        }
    }

    /// Validate invariants (split fraction strictly inside (0,1)).
    pub fn is_valid(&self) -> bool {
        match *self {
            Placement::Single(_) => true,
            Placement::Split { cpu_frac } => cpu_frac > 0.0 && cpu_frac < 1.0,
        }
    }
}

impl fmt::Display for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frac_on_single() {
        assert_eq!(Placement::CPU.frac_on(Proc::Cpu), 1.0);
        assert_eq!(Placement::CPU.frac_on(Proc::Gpu), 0.0);
        assert_eq!(Placement::GPU.frac_on(Proc::Gpu), 1.0);
    }

    #[test]
    fn frac_on_split_sums_to_one() {
        let s = Placement::Split { cpu_frac: 0.3 };
        assert!((s.frac_on(Proc::Cpu) + s.frac_on(Proc::Gpu) - 1.0).abs() < 1e-12);
        assert!(s.uses(Proc::Cpu) && s.uses(Proc::Gpu));
    }

    #[test]
    fn validity() {
        assert!(Placement::CPU.is_valid());
        assert!(Placement::Split { cpu_frac: 0.5 }.is_valid());
        assert!(!Placement::Split { cpu_frac: 0.0 }.is_valid());
        assert!(!Placement::Split { cpu_frac: 1.0 }.is_valid());
    }

    #[test]
    fn labels() {
        assert_eq!(Placement::CPU.label(), "cpu");
        assert_eq!(Placement::Split { cpu_frac: 0.25 }.label(), "split(0.25)");
    }
}
