//! CPU↔GPU data movement cost model.
//!
//! Mobile SoCs share one DRAM, so a "transfer" is not a PCIe copy but a
//! cache-coherency + mapping operation (CoDL builds on ION/SVM zero-copy
//! buffers): a fixed map/unmap + flush overhead, plus a bytes/bandwidth
//! term for the cache-line traffic. Both time and energy are modeled.

/// Transfer cost parameters (symmetric unless noted).
#[derive(Debug, Clone, Copy)]
pub struct TransferParams {
    /// Fixed map/unmap + cache-maintenance overhead per movement, s.
    pub map_overhead_s: f64,
    /// Effective bytes/s for the coherency traffic.
    pub bw: f64,
    /// Energy per byte moved (DRAM round trip ≈ 2 × ~110 pJ/B on LPDDR4X).
    pub energy_per_byte: f64,
    /// Fixed energy per map/unmap (driver + cache ops).
    pub map_energy_j: f64,
}

impl TransferParams {
    /// SD855-class shared-memory transfer constants.
    pub fn sd855() -> TransferParams {
        TransferParams {
            map_overhead_s: 80e-6,
            bw: 11.0e9,
            energy_per_byte: 0.22e-9,
            map_energy_j: 0.12e-3,
        }
    }

    /// Time to make `bytes` produced on one unit visible to the other.
    pub fn time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.map_overhead_s + bytes as f64 / self.bw
    }

    /// Energy for the same movement.
    pub fn energy(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.map_energy_j + bytes as f64 * self.energy_per_byte
    }
}

/// Bytes that must move between two consecutive ops given the CPU-side
/// share of the producer's output (`prev_cpu`) and the CPU-side share the
/// consumer needs (`next_cpu`), for a tensor of `bytes` total.
///
/// Model: the producer leaves `prev_cpu` of the tensor CPU-visible and the
/// rest GPU-visible (channel split); the consumer needs `next_cpu`
/// CPU-visible. The mismatch is what crosses the coherency boundary.
/// Split execution also pays a gather/scatter of the halves at the sync
/// point, captured by the caller adding the sync bytes.
pub fn boundary_bytes(bytes: u64, prev_cpu: f64, next_cpu: f64) -> u64 {
    debug_assert!((0.0..=1.0).contains(&prev_cpu));
    debug_assert!((0.0..=1.0).contains(&next_cpu));
    ((next_cpu - prev_cpu).abs() * bytes as f64).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bytes_is_free() {
        let t = TransferParams::sd855();
        assert_eq!(t.time(0), 0.0);
        assert_eq!(t.energy(0), 0.0);
    }

    #[test]
    fn overhead_dominates_small_transfers() {
        let t = TransferParams::sd855();
        // 4 KB: bytes term ≈ 0.4 µs ≪ 80 µs map overhead
        let small = t.time(4096);
        assert!(small > 0.9 * t.map_overhead_s && small < 1.2 * t.map_overhead_s);
    }

    #[test]
    fn bandwidth_dominates_large_transfers() {
        let t = TransferParams::sd855();
        // 44 MB ≈ 4 ms ≫ overhead
        let big = t.time(44_000_000);
        assert!(big > 10.0 * t.map_overhead_s);
    }

    #[test]
    fn boundary_bytes_same_placement_is_zero() {
        assert_eq!(boundary_bytes(1_000_000, 1.0, 1.0), 0);
        assert_eq!(boundary_bytes(1_000_000, 0.0, 0.0), 0);
        assert_eq!(boundary_bytes(1_000_000, 0.3, 0.3), 0);
    }

    #[test]
    fn boundary_bytes_full_move() {
        assert_eq!(boundary_bytes(1_000_000, 1.0, 0.0), 1_000_000);
        assert_eq!(boundary_bytes(1_000_000, 0.0, 1.0), 1_000_000);
    }

    #[test]
    fn boundary_bytes_partial() {
        assert_eq!(boundary_bytes(1_000_000, 0.25, 0.75), 500_000);
    }

    #[test]
    fn energy_scales_with_bytes() {
        let t = TransferParams::sd855();
        assert!(t.energy(10_000_000) > 5.0 * t.energy(1_000_000) * 0.5);
        assert!(t.energy(2_000_000) > t.energy(1_000_000));
    }
}
