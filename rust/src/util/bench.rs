//! Micro bench harness (criterion is not in the offline crate set).
//! `cargo bench` targets use `harness = false` and drive this directly:
//! warmup, timed iterations until a wall budget, mean/std/percentiles.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Iterations measured.
    pub iters: usize,
    /// Per-iteration wall time, seconds.
    pub summary: Summary,
}

impl BenchResult {
    /// Mean per-iteration time, milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean * 1e3
    }
    /// Mean per-iteration time, microseconds.
    pub fn mean_us(&self) -> f64 {
        self.summary.mean * 1e6
    }
}

/// Bench runner with a per-case wall-clock budget.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(100),
            budget: Duration::from_secs(1),
            min_iters: 10,
            max_iters: 100_000,
        }
    }
}

impl Bencher {
    /// Build with a warmup phase and a per-case measurement budget.
    pub fn new(warmup: Duration, budget: Duration) -> Self {
        Bencher {
            warmup,
            budget,
            ..Bencher::default()
        }
    }

    /// Quick settings for cheap statistical smoke runs in tests.
    pub fn fast() -> Self {
        Bencher {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(50),
            min_iters: 3,
            max_iters: 10_000,
        }
    }

    /// Time `f` repeatedly; returns per-iteration statistics.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Timed.
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while (b0.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            summary: Summary::of(&samples).unwrap(),
        }
    }
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render a table of bench results with aligned columns.
pub fn print_table(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "case", "iters", "mean", "p50", "p99", "std"
    );
    for r in results {
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12} {:>12}",
            r.name,
            r.iters,
            fmt_time(r.summary.mean),
            fmt_time(r.summary.p50),
            fmt_time(r.summary.p99),
            fmt_time(r.summary.std),
        );
    }
}

/// Human-readable seconds.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bencher::fast();
        let r = b.run("noop", || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 3);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
