//! Minimal recursive-descent JSON parser (the offline crate universe has
//! no `serde`), built for reading back the JSONL traces
//! [`crate::metrics::TraceObserver`] writes.
//!
//! Full RFC 8259 value grammar: objects, arrays, strings with `\uXXXX`
//! escapes, numbers, booleans, `null`. Numbers parse as `f64` (the trace
//! writer emits floats via `Display`, which round-trips exactly through
//! `str::parse::<f64>`). Errors carry a byte offset and a short
//! description; no panics on malformed input.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` | `false`.
    Bool(bool),
    /// Any JSON number (always carried as `f64`).
    Num(f64),
    /// A string literal, unescaped.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; key order is not preserved (sorted map).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse one JSON value from `input`, requiring it to consume the
    /// whole string (surrounding whitespace allowed).
    pub fn parse(input: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {} of JSON input", p.pos);
        }
        Ok(v)
    }

    /// Object member by key; `None` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as `f64` (numbers only).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as `u64` (integral, non-negative numbers only).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// The value as `usize` (integral, non-negative numbers only).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    /// The value as `&str` (strings only).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as `bool` (booleans only).
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as an array slice (arrays only).
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required object member of `f64` type, with a key-naming error.
    pub fn need_f64(&self, key: &str) -> Result<f64> {
        match self.get(key).and_then(Json::as_f64) {
            Some(x) => Ok(x),
            None => bail!("JSON object is missing numeric key `{key}`"),
        }
    }

    /// Required object member of `u64` type, with a key-naming error.
    pub fn need_u64(&self, key: &str) -> Result<u64> {
        match self.get(key).and_then(Json::as_u64) {
            Some(x) => Ok(x),
            None => bail!("JSON object is missing integer key `{key}`"),
        }
    }

    /// Required object member of `usize` type, with a key-naming error.
    pub fn need_usize(&self, key: &str) -> Result<usize> {
        self.need_u64(key).map(|x| x as usize)
    }

    /// Required object member of string type, with a key-naming error.
    pub fn need_str(&self, key: &str) -> Result<&str> {
        match self.get(key).and_then(Json::as_str) {
            Some(s) => Ok(s),
            None => bail!("JSON object is missing string key `{key}`"),
        }
    }

    /// Required object member of bool type, with a key-naming error.
    pub fn need_bool(&self, key: &str) -> Result<bool> {
        match self.get(key).and_then(Json::as_bool) {
            Some(b) => Ok(b),
            None => bail!("JSON object is missing boolean key `{key}`"),
        }
    }

    /// Required object member of array type, with a key-naming error.
    pub fn need_arr(&self, key: &str) -> Result<&[Json]> {
        match self.get(key).and_then(Json::as_arr) {
            Some(a) => Ok(a),
            None => bail!("JSON object is missing array key `{key}`"),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        match self.peek() {
            Some(c) if c == b => {
                self.pos += 1;
                Ok(())
            }
            Some(c) => bail!(
                "expected `{}` at byte {}, found `{}`",
                b as char,
                self.pos,
                c as char
            ),
            None => bail!("expected `{}` at byte {}, found end of input", b as char, self.pos),
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => bail!("unexpected byte `{}` at {}", b as char, self.pos),
            None => bail!("unexpected end of JSON input at byte {}", self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        let end = self.pos + word.len();
        if self.bytes.get(self.pos..end) == Some(word.as_bytes()) {
            self.pos = end;
            Ok(v)
        } else {
            bail!("malformed literal at byte {} (expected `{}`)", self.pos, word)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        match text.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => bail!("malformed number `{}` at byte {}", text, start),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string at byte {}", self.pos),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // note: surrogate pairs are not recombined;
                            // the trace writer never emits them
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            continue;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar (multi-byte safe): find the
                    // char boundary via str indexing on the remainder
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| anyhow::anyhow!("invalid UTF-8 at byte {}", self.pos))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let end = self.pos + 4;
        let Some(slice) = self.bytes.get(self.pos..end) else {
            bail!("truncated \\u escape at byte {}", self.pos);
        };
        let text = std::str::from_utf8(slice)
            .map_err(|_| anyhow::anyhow!("bad \\u escape at byte {}", self.pos))?;
        let cp = u32::from_str_radix(text, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape `{}` at byte {}", text, self.pos))?;
        self.pos = end;
        Ok(cp)
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected `,` or `}}` at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected `,` or `]` at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_trace_shaped_line() {
        let line = "{\"id\":12,\"stream\":0,\"arrival_s\":0.8421,\"shed\":false,\
                    \"ops\":[{\"op\":0,\"placement\":\"gpu\"}],\"x\":null}";
        let v = Json::parse(line).unwrap();
        assert_eq!(v.need_usize("id").unwrap(), 12);
        assert_eq!(v.need_f64("arrival_s").unwrap(), 0.8421);
        assert!(!v.need_bool("shed").unwrap());
        let ops = v.need_arr("ops").unwrap();
        assert_eq!(ops[0].need_str("placement").unwrap(), "gpu");
        assert_eq!(v.get("x"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn float_display_roundtrips_exactly() {
        for x in [0.1, 1.0 / 3.0, 6.02e23, -4.9e-324, 0.05] {
            let v = Json::parse(&format!("{x}")).unwrap();
            assert_eq!(v.as_f64().unwrap().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn unescapes_strings() {
        assert_eq!(
            Json::parse("\"a\\\"b\\\\c\\u000a\"").unwrap(),
            Json::Str("a\"b\\c\n".into())
        );
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"unterminated", "{} extra"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn need_helpers_name_the_key() {
        let v = Json::parse("{\"a\":1}").unwrap();
        let err = v.need_str("b").unwrap_err().to_string();
        assert!(err.contains("`b`"), "{err}");
    }
}
