//! Tiny leveled logger (the offline crate set has no `env_logger`).
//! Level comes from `ADAOPER_LOG` (error|warn|info|debug|trace); default
//! `info`. Thread-safe, writes to stderr.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log verbosity, ascending.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but survivable conditions.
    Warn = 1,
    /// Progress messages (default).
    Info = 2,
    /// Verbose diagnostics (`--verbose`).
    Debug = 3,
    /// Firehose.
    Trace = 4,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

/// Parse a level name (`error|warn|info|debug|trace`), as accepted by
/// both `ADAOPER_LOG` and the CLI `--log-level` option.
pub fn parse_level(s: &str) -> Option<Level> {
    match s {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn init_level() -> u8 {
    let lvl = std::env::var("ADAOPER_LOG")
        .ok()
        .and_then(|v| parse_level(&v))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current log level.
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == u8::MAX { init_level() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the log level programmatically (tests, CLI `-v`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// True when `l` would be emitted.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Emit a log line (use the `log_*!` macros instead).
pub fn log(l: Level, module: &str, args: fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    eprintln!("[{t:10.4}s {l} {module}] {args}");
}

/// Log at `error` level with `format!` syntax.
#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($a)*)) } }
/// Log at `warn` level with `format!` syntax.
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($a)*)) } }
/// Log at `info` level with `format!` syntax.
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($a)*)) } }
/// Log at `debug` level with `format!` syntax.
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($a)*)) } }
/// Log at `trace` level with `format!` syntax.
#[macro_export]
macro_rules! log_trace { ($($a:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, module_path!(), format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn parse_level_accepts_all_names() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("warn"), Some(Level::Warn));
        assert_eq!(parse_level("info"), Some(Level::Info));
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("trace"), Some(Level::Trace));
        assert_eq!(parse_level("loud"), None);
    }

    #[test]
    fn set_level_gates_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
