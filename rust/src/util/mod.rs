//! Utility substrates built in-repo (the offline crate universe has no
//! `rand`, `serde`, `criterion`, …): PRNG, statistics, ring buffer,
//! thread pool, logging, a JSON reader, and a micro bench harness.

pub mod bench;
pub mod json;
pub mod logger;
pub mod pool;
pub mod prng;
pub mod ring;
pub mod stats;

pub use prng::Prng;
pub use ring::RingBuffer;
pub use stats::Summary;
