//! Minimal thread pool (no tokio in the offline crate set). Its main user
//! is the fleet runner, which shards per-device simulations across the
//! workers via [`ThreadPool::map`].
//!
//! Panic safety: worker threads survive panicking jobs (the panic is
//! caught at the job boundary, so the pool never silently loses capacity),
//! and [`ThreadPool::map`] re-raises a task panic on the calling thread
//! after draining the batch — a panicking task surfaces instead of hanging
//! the caller or being silently dropped.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool executing boxed jobs FIFO.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Spawn a pool with `n` worker threads (`n > 0`).
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("adaoper-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            // catch panics so one bad job cannot kill the
                            // worker and silently shrink the pool
                            Ok(job) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break, // sender dropped → shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool {
            workers,
            tx: Some(tx),
        }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers alive");
    }

    /// Run `f` over every item, in parallel, returning results in input
    /// order. Blocks until done. If any task panics, the whole batch is
    /// still drained (workers stay alive) and the first panic payload is
    /// re-raised on the calling thread.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panic: Option<Box<dyn Any + Send>> = None;
        for _ in 0..n {
            let (i, r) = rx.recv().expect("pool job completed");
            match r {
                Ok(v) => out[i] = Some(v),
                Err(p) => panic = panic.or(Some(p)),
            }
        }
        if let Some(p) = panic {
            resume_unwind(p);
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel → workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang or panic
    }

    #[test]
    fn map_preserves_order_under_uneven_durations() {
        // later items finish *earlier* (decreasing sleep), so any
        // completion-order bug would scramble the output
        let pool = ThreadPool::new(4);
        let out = pool.map((0..24).collect::<Vec<u64>>(), |x| {
            std::thread::sleep(std::time::Duration::from_millis((24 - x) % 6));
            x * 7
        });
        assert_eq!(out, (0..24).map(|x| x * 7).collect::<Vec<u64>>());
    }

    #[test]
    fn map_panicking_task_surfaces_and_pool_survives() {
        let pool = ThreadPool::new(3);
        // the panic must propagate to the caller (not hang, not vanish) …
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.map(vec![0i64, 1, 2, 3, 4], |x| {
                if x == 2 {
                    panic!("task {x} exploded");
                }
                x
            })
        }));
        assert!(res.is_err(), "panicking map task was silently dropped");
        // … and the workers must still be alive afterwards
        let out = pool.map((0..10).collect::<Vec<i64>>(), |x| x + 1);
        assert_eq!(out, (1..=10).collect::<Vec<i64>>());
    }

    #[test]
    fn execute_panic_does_not_kill_worker() {
        let pool = ThreadPool::new(1); // single worker: a dead worker hangs map
        pool.execute(|| panic!("background job exploded"));
        let out = pool.map(vec![1u32, 2, 3], |x| x * 2);
        assert_eq!(out, vec![2, 4, 6]);
    }
}
