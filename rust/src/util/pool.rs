//! Minimal thread pool (no tokio in the offline crate set). Used by the
//! coordinator's per-processor executors and by the calibration sweep.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool executing boxed jobs FIFO.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Spawn a pool with `n` worker threads (`n > 0`).
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("adaoper-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped → shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool {
            workers,
            tx: Some(tx),
        }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers alive");
    }

    /// Run `f` over every item, in parallel, returning results in input
    /// order. Blocks until done.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rx.recv().expect("pool job completed");
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel → workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<i64>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang or panic
    }
}
