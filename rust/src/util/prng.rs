//! Deterministic pseudo-random number generation (xoshiro256** seeded via
//! SplitMix64). The offline crate set has no `rand`; everything stochastic
//! in the simulator, workload generators and property tests flows through
//! this module so runs are reproducible from a single `u64` seed.

/// xoshiro256** PRNG (Blackman & Vigna). Passes BigCrush; more than good
/// enough for simulation noise and property-test generation.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

/// The SplitMix64 increment ("golden gamma"): the amount [`splitmix64`]
/// advances its state by per step. Exported so stream-jumping code (the
/// fleet sampler's O(1) per-device seed derivation) stays in lockstep
/// with the generator by construction.
pub const SPLITMIX64_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// SplitMix64 step — used to expand a single seed into xoshiro state and as
/// a standalone mixing function.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(SPLITMIX64_GAMMA);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough mapping.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform i64 in `[lo, hi]` inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // avoid ln(0)
        -u.ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Split off an independent generator (for per-thread streams).
    pub fn split(&mut self) -> Prng {
        Prng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Prng::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut rng = Prng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Prng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let k = rng.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Prng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Prng::new(17);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Prng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Prng::new(23);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_is_reproducible_across_identical_roots() {
        // stream splitting must itself be deterministic: two roots with the
        // same seed yield children with identical streams
        let mut r1 = Prng::new(0xABCD);
        let mut r2 = Prng::new(0xABCD);
        let mut c1 = r1.split();
        let mut c2 = r2.split();
        for _ in 0..256 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
    }

    #[test]
    fn split_child_independent_of_parent_continuation() {
        // the child stream must not collide with the parent's continuation
        let mut parent = Prng::new(31);
        let mut child = parent.split();
        let overlap = (0..256)
            .filter(|_| parent.next_u64() == child.next_u64())
            .count();
        assert_eq!(overlap, 0);
    }

    #[test]
    fn derived_distributions_deterministic_per_seed() {
        let sample = |seed: u64| -> Vec<f64> {
            let mut rng = Prng::new(seed);
            let mut out = Vec::new();
            for _ in 0..50 {
                out.push(rng.range(-2.0, 9.0));
                out.push(rng.normal_with(3.0, 0.5));
                out.push(rng.exponential(4.0));
            }
            out
        };
        assert_eq!(sample(77), sample(77));
        assert_ne!(sample(77), sample(78));
    }

    #[test]
    fn choose_covers_all_elements() {
        let mut rng = Prng::new(37);
        let xs = [10u32, 20, 30, 40];
        let mut seen = [false; 4];
        for _ in 0..500 {
            let v = *rng.choose(&xs);
            seen[(v / 10 - 1) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn int_range_inclusive_bounds() {
        let mut rng = Prng::new(29);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..2_000 {
            let v = rng.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }
}
