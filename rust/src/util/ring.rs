//! Fixed-capacity ring buffer. Backs the profiler's residual history (the
//! GRU input window) and the resource monitor's recent-state traces without
//! allocating on the hot path.

/// Fixed-capacity FIFO ring buffer that overwrites the oldest element once
/// full. Iteration order is oldest → newest.
#[derive(Debug, Clone)]
pub struct RingBuffer<T> {
    buf: Vec<T>,
    cap: usize,
    head: usize, // index of oldest element
    len: usize,
}

impl<T: Clone> RingBuffer<T> {
    /// Create a ring buffer holding at most `cap` elements (`cap > 0`).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "ring buffer capacity must be > 0");
        RingBuffer {
            buf: Vec::with_capacity(cap),
            cap,
            head: 0,
            len: 0,
        }
    }

    /// Append, overwriting the oldest element when full. Returns the evicted
    /// element, if any.
    pub fn push(&mut self, value: T) -> Option<T> {
        if self.len < self.cap {
            // Still filling: physical index == logical order.
            let idx = (self.head + self.len) % self.cap;
            if idx == self.buf.len() {
                self.buf.push(value);
            } else {
                self.buf[idx] = value;
            }
            self.len += 1;
            None
        } else {
            let evicted = std::mem::replace(&mut self.buf[self.head], value);
            self.head = (self.head + 1) % self.cap;
            Some(evicted)
        }
    }

    /// Number of stored elements.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is stored.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when the next push will evict.
    pub fn is_full(&self) -> bool {
        self.len == self.cap
    }

    /// Maximum elements held.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Element `i` in logical order (0 = oldest).
    pub fn get(&self, i: usize) -> Option<&T> {
        if i >= self.len {
            None
        } else {
            Some(&self.buf[(self.head + i) % self.cap])
        }
    }

    /// Newest element.
    pub fn last(&self) -> Option<&T> {
        if self.len == 0 {
            None
        } else {
            self.get(self.len - 1)
        }
    }

    /// Iterate oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &T> + '_ {
        (0..self.len).map(move |i| self.get(i).unwrap())
    }

    /// Copy out into a Vec, oldest → newest.
    pub fn to_vec(&self) -> Vec<T> {
        self.iter().cloned().collect()
    }

    /// Drop all elements.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_then_wraps() {
        let mut r = RingBuffer::new(3);
        assert_eq!(r.push(1), None);
        assert_eq!(r.push(2), None);
        assert_eq!(r.push(3), None);
        assert!(r.is_full());
        assert_eq!(r.push(4), Some(1));
        assert_eq!(r.to_vec(), vec![2, 3, 4]);
    }

    #[test]
    fn logical_order_preserved_across_many_wraps() {
        let mut r = RingBuffer::new(4);
        for i in 0..100 {
            r.push(i);
        }
        assert_eq!(r.to_vec(), vec![96, 97, 98, 99]);
        assert_eq!(*r.last().unwrap(), 99);
        assert_eq!(*r.get(0).unwrap(), 96);
    }

    #[test]
    fn get_out_of_range_is_none() {
        let mut r = RingBuffer::new(2);
        r.push(1);
        assert!(r.get(1).is_none());
        assert_eq!(*r.get(0).unwrap(), 1);
    }

    #[test]
    fn clear_resets() {
        let mut r = RingBuffer::new(2);
        r.push(1);
        r.push(2);
        r.push(3);
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.push(9), None);
        assert_eq!(r.to_vec(), vec![9]);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_panics() {
        let _ = RingBuffer::<u8>::new(0);
    }
}
