//! Descriptive statistics used across metrics, the profiler evaluation and
//! bench reporting: summaries, percentiles, error metrics, and a small
//! online (Welford) accumulator.

/// Summary statistics of a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub count: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median.
    pub p50: f64,
    /// 90th percentile.
    pub p90: f64,
    /// 99th percentile.
    pub p99: f64,
}

impl Summary {
    /// Compute a summary of `xs`. Returns `None` for an empty slice.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(f64::total_cmp); // NaN-safe: sorts last, never panics
        let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / sorted.len() as f64;
        Some(Summary {
            count: sorted.len(),
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        })
    }
}

/// Percentile (linear interpolation) of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Percentile of an unsorted slice (copies + sorts).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp); // NaN-safe: sorts last, never panics
    percentile_sorted(&sorted, p)
}

/// Mean absolute percentage error of predictions vs truth (both non-empty,
/// same length; truth entries must be non-zero).
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    pred.iter()
        .zip(truth)
        .map(|(p, t)| ((p - t) / t).abs())
        .sum::<f64>()
        / pred.len() as f64
        * 100.0
}

/// Root-mean-square error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    (pred.iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / pred.len() as f64)
        .sqrt()
}

/// Coefficient of determination (R²).
pub fn r2(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mean = truth.iter().sum::<f64>() / truth.len() as f64;
    let ss_tot: f64 = truth.iter().map(|t| (t - mean) * (t - mean)).sum();
    let ss_res: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            f64::NEG_INFINITY
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Online mean/variance accumulator (Welford). O(1) memory, numerically
/// stable; used by the resource monitor and metrics sinks.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: usize,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Samples seen.
    pub fn count(&self) -> usize {
        self.n
    }

    /// Running mean (0 before any sample).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance. 0 for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Exponentially-weighted moving average, used for utilization smoothing in
/// the DVFS governor and the profiler's drift detector.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` in (0, 1]: weight of the newest observation.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ewma { alpha, value: None }
    }

    /// Fold in one observation and return the new average.
    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    /// Current average (None before any observation).
    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn nan_input_sorts_last_instead_of_panicking() {
        // regression: the comparators used `partial_cmp(..).unwrap()`,
        // which panicked on NaN; total_cmp sorts NaN after every number
        let p = percentile(&[1.0, f64::NAN, 0.5], 50.0);
        assert_eq!(p, 1.0);
        let s = Summary::of(&[0.5, f64::NAN, 1.0]).unwrap();
        assert_eq!(s.p50, 1.0);
        assert_eq!(s.min, 0.5);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&xs, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    #[should_panic]
    fn percentile_empty_panics() {
        let _ = percentile_sorted(&[], 50.0);
    }

    #[test]
    #[should_panic]
    fn percentile_out_of_range_panics() {
        let _ = percentile(&[1.0, 2.0], 101.0);
    }

    #[test]
    fn percentile_duplicate_heavy_input() {
        // 9 copies of 5.0 and one 1.0: every interior percentile between
        // the duplicates is the duplicate value itself
        let xs = [5.0, 5.0, 5.0, 1.0, 5.0, 5.0, 5.0, 5.0, 5.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        for p in [20.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(percentile(&xs, p), 5.0, "p={p}");
        }
        // all-equal input: constant at every percentile
        let flat = [3.0; 7];
        for p in [0.0, 37.0, 50.0, 99.9, 100.0] {
            assert_eq!(percentile(&flat, p), 3.0, "p={p}");
        }
    }

    #[test]
    fn summary_single_element() {
        let s = Summary::of(&[4.25]).unwrap();
        assert_eq!(s.count, 1);
        assert_eq!((s.min, s.max), (4.25, 4.25));
        assert_eq!((s.p50, s.p90, s.p99), (4.25, 4.25, 4.25));
        assert_eq!(s.mean, 4.25);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn summary_duplicate_heavy() {
        let s = Summary::of(&[2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 2.0]).unwrap();
        assert_eq!(s.count, 8);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!((s.p50, s.p90, s.p99), (2.0, 2.0, 2.0));
    }

    #[test]
    fn mape_known_value() {
        // |10-8|/8 + |20-25|/25 = 0.25 + 0.2 → mean 0.225 → 22.5%
        let m = mape(&[10.0, 20.0], &[8.0, 25.0]);
        assert!((m - 22.5).abs() < 1e-9, "m={m}");
    }

    #[test]
    fn rmse_known_value() {
        let e = rmse(&[1.0, 2.0], &[0.0, 4.0]);
        assert!((e - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn r2_perfect_prediction() {
        let t = [1.0, 2.0, 3.0];
        assert!((r2(&t, &t) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r2_mean_prediction_is_zero() {
        let truth = [1.0, 2.0, 3.0];
        let pred = [2.0, 2.0, 2.0];
        assert!(r2(&pred, &truth).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges_to_constant() {
        let mut e = Ewma::new(0.3);
        for _ in 0..200 {
            e.push(5.0);
        }
        assert!((e.value().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_first_value_passthrough() {
        let mut e = Ewma::new(0.1);
        assert_eq!(e.push(42.0), 42.0);
    }
}
