//! Request arrival processes for the serving experiments: Poisson (open
//! loop, e.g. voice-assistant queries), periodic (camera frames), and a
//! two-state MMPP (Markov-modulated Poisson process) for bursty traffic —
//! the arrival shape dynamic batching exists for, since bursts create the
//! co-resident same-stream requests a batch amortizes over.

use crate::util::Prng;

/// An arrival process generating inter-arrival gaps.
#[derive(Debug, Clone)]
pub enum Arrival {
    /// Poisson with mean rate `hz`.
    Poisson {
        /// Mean arrival rate, Hz.
        hz: f64,
    },
    /// Strictly periodic at `hz` with optional jitter fraction.
    Periodic {
        /// Frame rate, Hz.
        hz: f64,
        /// Uniform jitter as a fraction of the period.
        jitter: f64,
    },
    /// Two-state Markov-modulated Poisson process: Poisson arrivals whose
    /// rate switches between a calm and a burst level, with exponentially
    /// distributed dwell times per state. The stationary mean rate is
    /// `(dwell_low · hz_low + dwell_high · hz_high) / (dwell_low + dwell_high)`.
    Mmpp {
        /// Arrival rate in the calm state, Hz.
        hz_low: f64,
        /// Arrival rate in the burst state, Hz.
        hz_high: f64,
        /// Mean dwell time in the calm state, seconds.
        dwell_low_s: f64,
        /// Mean dwell time in the burst state, seconds.
        dwell_high_s: f64,
    },
}

impl Arrival {
    /// Parse a process kind (`poisson` | `periodic` | `mmpp`) at mean rate
    /// `hz`. `jitter` applies to periodic arrivals only (fraction of the
    /// period; the historical hard-coded value was 0.02). `mmpp` derives a
    /// canonical bursty shape with stationary mean `hz`: a calm state at
    /// `hz / 2` (mean dwell 2 s) and a burst state at `3 · hz` (mean dwell
    /// 0.5 s), so 20 % of the time carries 60 % of the traffic.
    pub fn parse(kind: &str, hz: f64, jitter: f64) -> Option<Arrival> {
        match kind {
            "poisson" => Some(Arrival::Poisson { hz }),
            "periodic" => Some(Arrival::Periodic { hz, jitter }),
            "mmpp" => Some(Arrival::Mmpp {
                hz_low: 0.5 * hz,
                hz_high: 3.0 * hz,
                dwell_low_s: 2.0,
                dwell_high_s: 0.5,
            }),
            _ => None,
        }
    }

    /// Next inter-arrival gap in seconds. For [`Arrival::Mmpp`] — which is
    /// stateful over a timeline — this draws the modulating state as seen
    /// *by an arrival* (states weighted by the arrivals they carry,
    /// `dwell × rate`, not by wall time), so the mean gap is exactly
    /// `1 / rate_hz()`; [`Arrival::timestamps`] runs the exact state
    /// machine instead.
    pub fn next_gap(&self, rng: &mut Prng) -> f64 {
        match *self {
            Arrival::Poisson { hz } => rng.exponential(hz),
            Arrival::Periodic { hz, jitter } => {
                let base = 1.0 / hz;
                base * (1.0 + jitter * (rng.f64() * 2.0 - 1.0))
            }
            Arrival::Mmpp {
                hz_low,
                hz_high,
                dwell_low_s,
                dwell_high_s,
            } => {
                let w_high = dwell_high_s * hz_high;
                let w_low = dwell_low_s * hz_low;
                let p_high = w_high / (w_low + w_high).max(1e-12);
                let rate = if rng.f64() < p_high { hz_high } else { hz_low };
                rng.exponential(rate)
            }
        }
    }

    /// Generate all arrival timestamps within `[0, duration_s)`.
    pub fn timestamps(&self, duration_s: f64, rng: &mut Prng) -> Vec<f64> {
        if let Arrival::Mmpp {
            hz_low,
            hz_high,
            dwell_low_s,
            dwell_high_s,
        } = *self
        {
            return mmpp_timestamps(
                duration_s, hz_low, hz_high, dwell_low_s, dwell_high_s, rng,
            );
        }
        let mut t = 0.0;
        let mut out = Vec::new();
        loop {
            t += self.next_gap(rng);
            if t >= duration_s {
                return out;
            }
            out.push(t);
        }
    }

    /// Mean arrival rate (stationary mean for [`Arrival::Mmpp`]).
    pub fn rate_hz(&self) -> f64 {
        match *self {
            Arrival::Poisson { hz } | Arrival::Periodic { hz, .. } => hz,
            Arrival::Mmpp {
                hz_low,
                hz_high,
                dwell_low_s,
                dwell_high_s,
            } => {
                (dwell_low_s * hz_low + dwell_high_s * hz_high)
                    / (dwell_low_s + dwell_high_s)
            }
        }
    }
}

/// The MMPP state machine: alternate calm/burst episodes with exponential
/// dwell times, drawing Poisson gaps at the active state's rate. A gap
/// crossing the episode boundary is discarded and redrawn from the
/// boundary at the new rate — exact for exponential gaps (memorylessness).
fn mmpp_timestamps(
    duration_s: f64,
    hz_low: f64,
    hz_high: f64,
    dwell_low_s: f64,
    dwell_high_s: f64,
    rng: &mut Prng,
) -> Vec<f64> {
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut high = false; // episodes start calm
    let mut state_end = rng.exponential(1.0 / dwell_low_s.max(1e-9));
    while t < duration_s {
        let rate = if high { hz_high } else { hz_low };
        let gap = rng.exponential(rate.max(1e-9));
        if t + gap < state_end {
            t += gap;
            if t >= duration_s {
                break;
            }
            out.push(t);
        } else {
            t = state_end;
            high = !high;
            let dwell = if high { dwell_high_s } else { dwell_low_s };
            state_end = t + rng.exponential(1.0 / dwell.max(1e-9));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate() {
        let a = Arrival::Poisson { hz: 20.0 };
        let mut rng = Prng::new(1);
        let ts = a.timestamps(100.0, &mut rng);
        let rate = ts.len() as f64 / 100.0;
        assert!((rate - 20.0).abs() < 1.5, "rate {rate}");
    }

    #[test]
    fn periodic_is_regular() {
        let a = Arrival::Periodic { hz: 30.0, jitter: 0.0 };
        let mut rng = Prng::new(2);
        let ts = a.timestamps(1.0, &mut rng);
        // 1/30, 2/30, …: 29 or 30 points depending on fp accumulation
        assert!(ts.len() == 29 || ts.len() == 30, "len {}", ts.len());
        for w in ts.windows(2) {
            assert!((w[1] - w[0] - 1.0 / 30.0).abs() < 1e-9);
        }
    }

    #[test]
    fn timestamps_sorted_and_bounded() {
        let a = Arrival::Poisson { hz: 50.0 };
        let mut rng = Prng::new(3);
        let ts = a.timestamps(5.0, &mut rng);
        for w in ts.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(ts.iter().all(|&t| t < 5.0));
    }

    #[test]
    fn parse_kinds() {
        assert!(matches!(
            Arrival::parse("poisson", 5.0, 0.02),
            Some(Arrival::Poisson { .. })
        ));
        assert!(matches!(
            Arrival::parse("periodic", 5.0, 0.02),
            Some(Arrival::Periodic { .. })
        ));
        assert!(matches!(
            Arrival::parse("mmpp", 5.0, 0.02),
            Some(Arrival::Mmpp { .. })
        ));
        assert!(Arrival::parse("burst", 5.0, 0.02).is_none());
    }

    #[test]
    fn parse_passes_jitter_through() {
        match Arrival::parse("periodic", 5.0, 0.25) {
            Some(Arrival::Periodic { jitter, .. }) => assert_eq!(jitter, 0.25),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn mmpp_stationary_mean_matches_requested_rate() {
        let a = Arrival::parse("mmpp", 20.0, 0.0).unwrap();
        assert!((a.rate_hz() - 20.0).abs() < 1e-9, "mean {}", a.rate_hz());
        // empirical mean over a long horizon tracks the stationary rate
        // (wide tolerance: burstiness inflates the count variance well
        // past Poisson's)
        let mut rng = Prng::new(7);
        let ts = a.timestamps(600.0, &mut rng);
        let rate = ts.len() as f64 / 600.0;
        assert!((rate - 20.0).abs() < 3.0, "empirical rate {rate}");
    }

    #[test]
    fn mmpp_next_gap_mean_matches_rate() {
        // arrival-weighted state mixing: the mean stateless gap must equal
        // 1 / stationary rate (time-weighted mixing would be 40% short)
        let a = Arrival::parse("mmpp", 20.0, 0.0).unwrap();
        let mut rng = Prng::new(5);
        let n = 20000;
        let mean: f64 = (0..n).map(|_| a.next_gap(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean * 20.0 - 1.0).abs() < 0.05, "mean gap {mean}");
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // index of dispersion of counts in 1 s windows: 1 for Poisson, far
        // above 1 for a rate-modulated process
        let dispersion = |ts: &[f64], horizon: f64| {
            let n = horizon as usize;
            let mut counts = vec![0f64; n];
            for &t in ts {
                counts[(t as usize).min(n - 1)] += 1.0;
            }
            let mean = counts.iter().sum::<f64>() / n as f64;
            let var = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>()
                / n as f64;
            var / mean
        };
        let mut rng = Prng::new(11);
        let mmpp = Arrival::parse("mmpp", 20.0, 0.0)
            .unwrap()
            .timestamps(200.0, &mut rng);
        let mut rng = Prng::new(11);
        let poisson = Arrival::Poisson { hz: 20.0 }.timestamps(200.0, &mut rng);
        let d_mmpp = dispersion(&mmpp, 200.0);
        let d_poisson = dispersion(&poisson, 200.0);
        assert!(d_poisson < 1.6, "poisson dispersion {d_poisson}");
        assert!(
            d_mmpp > d_poisson * 1.5,
            "mmpp dispersion {d_mmpp} not bursty vs poisson {d_poisson}"
        );
    }

    #[test]
    fn mmpp_timestamps_sorted_and_bounded() {
        let a = Arrival::parse("mmpp", 40.0, 0.0).unwrap();
        let mut rng = Prng::new(13);
        let ts = a.timestamps(10.0, &mut rng);
        assert!(!ts.is_empty());
        for w in ts.windows(2) {
            assert!(w[0] < w[1], "{} !< {}", w[0], w[1]);
        }
        assert!(ts.iter().all(|&t| t < 10.0));
    }
}
