//! Request arrival processes for the serving experiments: Poisson (open
//! loop, e.g. voice-assistant queries) and periodic (camera frames).

use crate::util::Prng;

/// An arrival process generating inter-arrival gaps.
#[derive(Debug, Clone)]
pub enum Arrival {
    /// Poisson with mean rate `hz`.
    Poisson {
        /// Mean arrival rate, Hz.
        hz: f64,
    },
    /// Strictly periodic at `hz` with optional jitter fraction.
    Periodic {
        /// Frame rate, Hz.
        hz: f64,
        /// Uniform jitter as a fraction of the period.
        jitter: f64,
    },
}

impl Arrival {
    /// Parse a process kind (`poisson` | `periodic`) at mean rate `hz`.
    pub fn parse(kind: &str, hz: f64) -> Option<Arrival> {
        match kind {
            "poisson" => Some(Arrival::Poisson { hz }),
            "periodic" => Some(Arrival::Periodic { hz, jitter: 0.02 }),
            _ => None,
        }
    }

    /// Next inter-arrival gap in seconds.
    pub fn next_gap(&self, rng: &mut Prng) -> f64 {
        match *self {
            Arrival::Poisson { hz } => rng.exponential(hz),
            Arrival::Periodic { hz, jitter } => {
                let base = 1.0 / hz;
                base * (1.0 + jitter * (rng.f64() * 2.0 - 1.0))
            }
        }
    }

    /// Generate all arrival timestamps within `[0, duration_s)`.
    pub fn timestamps(&self, duration_s: f64, rng: &mut Prng) -> Vec<f64> {
        let mut t = 0.0;
        let mut out = Vec::new();
        loop {
            t += self.next_gap(rng);
            if t >= duration_s {
                return out;
            }
            out.push(t);
        }
    }

    /// Mean arrival rate.
    pub fn rate_hz(&self) -> f64 {
        match *self {
            Arrival::Poisson { hz } | Arrival::Periodic { hz, .. } => hz,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate() {
        let a = Arrival::Poisson { hz: 20.0 };
        let mut rng = Prng::new(1);
        let ts = a.timestamps(100.0, &mut rng);
        let rate = ts.len() as f64 / 100.0;
        assert!((rate - 20.0).abs() < 1.5, "rate {rate}");
    }

    #[test]
    fn periodic_is_regular() {
        let a = Arrival::Periodic { hz: 30.0, jitter: 0.0 };
        let mut rng = Prng::new(2);
        let ts = a.timestamps(1.0, &mut rng);
        // 1/30, 2/30, …: 29 or 30 points depending on fp accumulation
        assert!(ts.len() == 29 || ts.len() == 30, "len {}", ts.len());
        for w in ts.windows(2) {
            assert!((w[1] - w[0] - 1.0 / 30.0).abs() < 1e-9);
        }
    }

    #[test]
    fn timestamps_sorted_and_bounded() {
        let a = Arrival::Poisson { hz: 50.0 };
        let mut rng = Prng::new(3);
        let ts = a.timestamps(5.0, &mut rng);
        for w in ts.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(ts.iter().all(|&t| t < 5.0));
    }

    #[test]
    fn parse_kinds() {
        assert!(matches!(
            Arrival::parse("poisson", 5.0),
            Some(Arrival::Poisson { .. })
        ));
        assert!(matches!(
            Arrival::parse("periodic", 5.0),
            Some(Arrival::Periodic { .. })
        ));
        assert!(Arrival::parse("burst", 5.0).is_none());
    }
}
