//! The paper's workload-condition presets (§3, Figure 2 setup):
//!
//! * **moderate** — CPU pinned 1.49 GHz, GPU 499 MHz, average CPU
//!   utilization ≈ 78.8 % *measured during serving* (background ≈ 35 % +
//!   the DL task's own share).
//! * **high** — CPU pinned 0.88 GHz, GPU 427 MHz, average CPU utilization
//!   ≈ 91.3 % (background ≈ 55 % with strong bursts).
//!
//! Background burstiness rises with the condition level — that is the
//! dynamic CoDL's offline predictors miss and AdaOper's runtime profiler
//! tracks (DESIGN.md §5.4).

use crate::soc::device::ConditionSpec;

/// Named condition preset.
#[derive(Debug, Clone)]
pub struct WorkloadCondition {
    /// The full device-facing condition specification.
    pub spec: ConditionSpec,
}

impl WorkloadCondition {
    /// Unloaded device, governors free-running.
    pub fn idle() -> WorkloadCondition {
        WorkloadCondition {
            spec: ConditionSpec {
                name: "idle",
                cpu_freq_hz: None,
                gpu_freq_hz: None,
                cpu_bg_mean: 0.05,
                cpu_bg_sigma: 0.02,
                cpu_burst: 0.05,
                gpu_bg_mean: 0.03,
                gpu_bg_sigma: 0.01,
                gpu_burst: 0.03,
                bw_ambient: 1.0,
                drift_sigma: 0.03,
            },
        }
    }

    /// Paper's moderate condition.
    pub fn moderate() -> WorkloadCondition {
        WorkloadCondition {
            spec: ConditionSpec {
                name: "moderate",
                cpu_freq_hz: Some(1.49e9),
                gpu_freq_hz: Some(499e6),
                cpu_bg_mean: 0.35,
                cpu_bg_sigma: 0.03,
                cpu_burst: 0.07,
                gpu_bg_mean: 0.08,
                gpu_bg_sigma: 0.02,
                gpu_burst: 0.05,
                bw_ambient: 0.92,
                drift_sigma: 0.05,
            },
        }
    }

    /// Paper's high condition.
    pub fn high() -> WorkloadCondition {
        WorkloadCondition {
            spec: ConditionSpec {
                name: "high",
                cpu_freq_hz: Some(0.88e9),
                gpu_freq_hz: Some(427e6),
                cpu_bg_mean: 0.55,
                cpu_bg_sigma: 0.06,
                cpu_burst: 0.16,
                gpu_bg_mean: 0.12,
                gpu_bg_sigma: 0.03,
                gpu_burst: 0.08,
                bw_ambient: 0.82,
                drift_sigma: 0.10,
            },
        }
    }

    /// Preset by name (`idle` | `moderate` | `high`).
    pub fn by_name(name: &str) -> Option<WorkloadCondition> {
        match name {
            "idle" => Some(WorkloadCondition::idle()),
            "moderate" => Some(WorkloadCondition::moderate()),
            "high" => Some(WorkloadCondition::high()),
            _ => None,
        }
    }

    /// Preset name.
    pub fn name(&self) -> &'static str {
        self.spec.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_frequencies() {
        let m = WorkloadCondition::moderate();
        assert_eq!(m.spec.cpu_freq_hz, Some(1.49e9));
        assert_eq!(m.spec.gpu_freq_hz, Some(499e6));
        let h = WorkloadCondition::high();
        assert_eq!(h.spec.cpu_freq_hz, Some(0.88e9));
        assert_eq!(h.spec.gpu_freq_hz, Some(427e6));
    }

    #[test]
    fn high_is_more_loaded_and_burstier_than_moderate() {
        let m = WorkloadCondition::moderate().spec;
        let h = WorkloadCondition::high().spec;
        assert!(h.cpu_bg_mean > m.cpu_bg_mean);
        assert!(h.cpu_burst > m.cpu_burst);
        assert!(h.drift_sigma > m.drift_sigma);
        assert!(h.bw_ambient < m.bw_ambient);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["idle", "moderate", "high"] {
            assert_eq!(WorkloadCondition::by_name(n).unwrap().name(), n);
        }
        assert!(WorkloadCondition::by_name("extreme").is_none());
    }
}
