//! Workload modeling: the paper's device conditions ([`conditions`]),
//! request arrival processes ([`arrival`]), and condition-switch traces for
//! the responsiveness/adaptation experiments ([`trace`]).

pub mod arrival;
pub mod conditions;
pub mod trace;

pub use arrival::Arrival;
pub use conditions::WorkloadCondition;
