//! Condition-switch traces: timed sequences of workload conditions used by
//! the adaptation/responsiveness experiments (ablations A1, A3, A4).

use super::conditions::WorkloadCondition;

/// One phase of a trace.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Condition held during this phase.
    pub condition: WorkloadCondition,
    /// Phase length, seconds.
    pub duration_s: f64,
}

/// A piecewise-constant condition trace.
#[derive(Debug, Clone)]
pub struct ConditionTrace {
    /// Phases in play order.
    pub phases: Vec<Phase>,
}

impl ConditionTrace {
    /// Build from non-empty phases with positive durations.
    pub fn new(phases: Vec<Phase>) -> Self {
        assert!(!phases.is_empty());
        assert!(phases.iter().all(|p| p.duration_s > 0.0));
        ConditionTrace { phases }
    }

    /// The paper's implicit scenario: start moderate, degrade to high.
    pub fn moderate_to_high(seg_s: f64) -> ConditionTrace {
        ConditionTrace::new(vec![
            Phase {
                condition: WorkloadCondition::moderate(),
                duration_s: seg_s,
            },
            Phase {
                condition: WorkloadCondition::high(),
                duration_s: seg_s,
            },
        ])
    }

    /// Stress trace: idle → moderate → high → moderate (A1/A4).
    pub fn stairs(seg_s: f64) -> ConditionTrace {
        ConditionTrace::new(vec![
            Phase {
                condition: WorkloadCondition::idle(),
                duration_s: seg_s,
            },
            Phase {
                condition: WorkloadCondition::moderate(),
                duration_s: seg_s,
            },
            Phase {
                condition: WorkloadCondition::high(),
                duration_s: seg_s,
            },
            Phase {
                condition: WorkloadCondition::moderate(),
                duration_s: seg_s,
            },
        ])
    }

    /// Sum of all phase durations.
    pub fn total_duration_s(&self) -> f64 {
        self.phases.iter().map(|p| p.duration_s).sum()
    }

    /// Condition active at time `t` (clamps to the last phase).
    pub fn at(&self, t: f64) -> &WorkloadCondition {
        let mut acc = 0.0;
        for p in &self.phases {
            acc += p.duration_s;
            if t < acc {
                return &p.condition;
            }
        }
        &self.phases.last().unwrap().condition
    }

    /// Times at which the condition changes.
    pub fn switch_times(&self) -> Vec<f64> {
        let mut acc = 0.0;
        let mut out = Vec::new();
        for p in &self.phases[..self.phases.len() - 1] {
            acc += p.duration_s;
            out.push(acc);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_selects_phase() {
        let t = ConditionTrace::stairs(10.0);
        assert_eq!(t.at(0.0).name(), "idle");
        assert_eq!(t.at(10.5).name(), "moderate");
        assert_eq!(t.at(25.0).name(), "high");
        assert_eq!(t.at(35.0).name(), "moderate");
        assert_eq!(t.at(999.0).name(), "moderate"); // clamp
    }

    #[test]
    fn durations_and_switches() {
        let t = ConditionTrace::moderate_to_high(5.0);
        assert_eq!(t.total_duration_s(), 10.0);
        assert_eq!(t.switch_times(), vec![5.0]);
    }

    #[test]
    #[should_panic]
    fn empty_trace_panics() {
        let _ = ConditionTrace::new(vec![]);
    }
}
