//! Arena-recycling correctness: no request state leaks across slab reuse.
//!
//! The stage pipeline allocates every request's `out_cpu` buffer from a
//! [`RequestArena`] (PR 7) and recycles the buffer at completion. The
//! byte-safety claim is that a recycled buffer behaves exactly like a
//! fresh allocation: fully overwritten, regardless of what (and how much)
//! the previous occupant left in it.
//!
//! The pin: run a fixed-seed scenario on a **fresh** arena and again on a
//! **deliberately polluted** arena — one warmed by a different engine
//! serving a different (larger) model, so its pooled buffers hold
//! wrong-length garbage from foreign requests — and require the rendered
//! `ServingReport` rows to be byte-identical. One engine run twice is
//! *not* comparable (its device clock and profiler state persist across
//! runs), hence the two-engine transplant design.

use std::sync::OnceLock;

use adaoper::config::schema::{PolicyKind, SchedulerKind};
use adaoper::coordinator::{AdmissionPolicy, Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::profiler::calibrate::{calibrate_on, CalibConfig, OfflineModel};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::profiler::{EnergyProfiler, EwmaCorrector};
use adaoper::sim::RequestArena;
use adaoper::soc::device::DeviceConfig;
use adaoper::workload::Arrival;

fn calib() -> CalibConfig {
    CalibConfig {
        samples: 1200,
        seed: 5,
        gbdt: GbdtParams {
            trees: 40,
            ..Default::default()
        },
    }
}

fn offline() -> &'static OfflineModel {
    static OFF: OnceLock<OfflineModel> = OnceLock::new();
    OFF.get_or_init(|| calibrate_on(&calib(), &DeviceConfig::snapdragon_855()))
}

fn engine(seed: u64) -> Engine {
    let profiler = EnergyProfiler::with_correctors(offline().clone(), || {
        Box::new(EwmaCorrector::default())
    });
    Engine::with_profiler(
        EngineConfig {
            policy: PolicyKind::MaceGpu,
            scheduler: SchedulerKind::Edf,
            admission: AdmissionPolicy::DropLate,
            duration_s: 1.2,
            seed,
            calib: calib(),
            ..Default::default()
        },
        profiler,
    )
}

fn streams() -> Vec<StreamSpec> {
    vec![
        StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 30.0 }, 0.25),
        StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 20.0 }, 0.4),
    ]
}

/// An arena whose pooled buffers are leftovers from a *different* model's
/// requests — different op counts, different resident fractions.
fn polluted_arena() -> RequestArena {
    let mut polluter = engine(99);
    let foreign = vec![StreamSpec::new(
        0,
        zoo::yolov2(), // larger graph than either stream under test
        Arrival::Poisson { hz: 25.0 },
        0.6,
    )];
    polluter.run(&foreign).unwrap();
    let arena = polluter.take_arena();
    assert!(
        arena.pooled() > 0,
        "polluter run left no buffers to transplant"
    );
    arena
}

#[test]
fn recycled_arena_is_byte_identical_to_fresh() {
    // A: fresh arena (the pool starts empty; recycling still happens
    // within the run as completions feed later admissions)
    let mut fresh = engine(17);
    let row_fresh = fresh.run(&streams()).unwrap().row();
    let (alloc_fresh, recycled_fresh) = fresh.arena_stats();
    assert!(alloc_fresh > 0);

    // B: identical config/seed, but admissions draw from foreign garbage
    let mut warm = engine(17);
    warm.set_arena(polluted_arena());
    let row_warm = warm.run(&streams()).unwrap().row();
    let (_, recycled_warm) = warm.arena_stats();
    // the very first admission already finds a pooled (foreign) buffer,
    // so the warm engine must recycle strictly more than the fresh one
    assert!(
        recycled_warm > recycled_fresh,
        "transplanted pool was never drawn from ({recycled_warm} vs {recycled_fresh}) \
         — the test lost its teeth"
    );
    assert_eq!(
        row_fresh, row_warm,
        "recycled buffers leaked state into the serving report"
    );
}

#[test]
fn within_run_recycling_occurs_under_load() {
    // completions recycle into admissions within a single run: with 1.2 s
    // of overlapping arrivals the pool must turn over many times
    let mut e = engine(17);
    e.run(&streams()).unwrap();
    let mut e2 = engine(17);
    e2.set_arena(e.take_arena());
    e2.run(&streams()).unwrap();
    let (allocated, recycled) = e2.arena_stats();
    assert!(
        recycled > 0 && recycled <= allocated,
        "no recycling across runs: {allocated}/{recycled}"
    );
}
