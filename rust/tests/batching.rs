//! Batching subsystem tests: cost-model monotonicity properties, the
//! slack policy's no-manufactured-misses regression against the unbatched
//! oracle, and plan-cache behavior under the batch-bucketed key.

use std::collections::BTreeSet;

use adaoper::batching::cost::scale_op_cost;
use adaoper::batching::BatchConfig;
use adaoper::config::schema::{BatchPolicyKind, PolicyKind, SchedulerKind};
use adaoper::coordinator::request::RequestOutcome;
use adaoper::coordinator::{Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::sim::SimObserver;
use adaoper::soc::device::{Device, DeviceConfig, ExecCtx};
use adaoper::soc::latency::BatchScaling;
use adaoper::soc::{Placement, Proc};
use adaoper::workload::{Arrival, WorkloadCondition};

fn frozen_device() -> Device {
    let mut d = Device::new(DeviceConfig {
        noise_sigma: 0.0,
        drift_sigma: 0.0,
        ..DeviceConfig::snapdragon_855()
    });
    let mut c = WorkloadCondition::moderate().spec;
    c.cpu_bg_sigma = 0.0;
    c.cpu_burst = 0.0;
    c.gpu_bg_sigma = 0.0;
    c.gpu_burst = 0.0;
    c.drift_sigma = 0.0;
    d.apply_condition(&c);
    d
}

/// Property: ground-truth batched latency is non-decreasing in the batch
/// size, and per-request energy is non-increasing up to the unit's
/// amortization knee — on every op of the zoo model, both placements.
#[test]
fn batch_cost_model_monotone_on_ground_truth() {
    let d = frozen_device();
    let g = zoo::yolov2_tiny();
    for (placement, proc) in [(Placement::CPU, Proc::Cpu), (Placement::GPU, Proc::Gpu)] {
        let knee = BatchScaling::for_proc(proc).knee;
        for op in &g.ops {
            let ctx = ExecCtx::fresh(vec![placement.frac_on(Proc::Cpu); op.in_shapes.len()]);
            let mut prev_latency = 0.0;
            let mut prev_per_req_e = f64::INFINITY;
            for b in 1..=16usize {
                let c = d.expected_cost_batch(op, placement, &ctx, b);
                assert!(
                    c.latency_s >= prev_latency,
                    "op {} {placement:?} batch {b}: latency {} < {}",
                    op.name,
                    c.latency_s,
                    prev_latency
                );
                let per_req = c.energy_j / b as f64;
                if b <= knee {
                    assert!(
                        per_req <= prev_per_req_e * (1.0 + 1e-12),
                        "op {} {placement:?} batch {b}: per-req energy {} > {}",
                        op.name,
                        per_req,
                        prev_per_req_e
                    );
                }
                prev_latency = c.latency_s;
                prev_per_req_e = per_req;
            }
        }
    }
}

/// The analytic cost-model scaling mirrors the same properties (it is what
/// the DP and the slack policy plan with).
#[test]
fn batch_cost_model_monotone_on_analytic_scaling() {
    let d = frozen_device();
    let g = zoo::yolov2_tiny();
    for placement in [Placement::CPU, Placement::GPU] {
        for op in &g.ops {
            let ctx = ExecCtx::fresh(vec![placement.frac_on(Proc::Cpu); op.in_shapes.len()]);
            let single = d.expected_cost(op, placement, &ctx);
            let mut prev_latency = 0.0;
            let mut prev_per_req_e = f64::INFINITY;
            for b in 1..=4usize {
                let c = scale_op_cost(&single, b);
                assert!(c.latency_s >= prev_latency, "op {} batch {b}", op.name);
                let per_req = c.energy_j / b as f64;
                assert!(
                    per_req <= prev_per_req_e * (1.0 + 1e-12),
                    "op {} batch {b}: {} > {}",
                    op.name,
                    per_req,
                    prev_per_req_e
                );
                prev_latency = c.latency_s;
                prev_per_req_e = per_req;
            }
        }
    }
}

/// Records every request's deadline outcome by id.
#[derive(Default)]
struct MissSet {
    misses: BTreeSet<usize>,
    completed: usize,
}

impl SimObserver for MissSet {
    fn on_request_done(&mut self, outcome: &RequestOutcome, met_deadline: bool) {
        self.completed += 1;
        if !met_deadline {
            self.misses.insert(outcome.request.id);
        }
    }
}

fn quick_calib(seed: u64) -> CalibConfig {
    CalibConfig {
        samples: 1200,
        seed,
        gbdt: GbdtParams {
            trees: 40,
            ..Default::default()
        },
    }
}

fn bursty_run(batching: BatchConfig) -> (MissSet, adaoper::metrics::ServingReport) {
    let mut engine = Engine::new(EngineConfig {
        policy: PolicyKind::MaceGpu,
        scheduler: SchedulerKind::Edf,
        duration_s: 4.0,
        seed: 23,
        calib: quick_calib(23),
        batching,
        ..Default::default()
    });
    // bursty but sub-saturation on average, with a generous SLO: bursts
    // create the co-residency batches need, while the unbatched oracle
    // comfortably meets every deadline — so any slack-run miss would be a
    // manufactured one
    let stream = StreamSpec::new(
        0,
        zoo::yolov2_tiny(),
        Arrival::parse("mmpp", 20.0, 0.0).expect("mmpp parses"),
        1.5,
    );
    let mut probe = MissSet::default();
    let report = engine.run_observed(&[stream], &mut [&mut probe]).unwrap();
    (probe, report)
}

/// Regression: the slack policy must not miss a deadline the unbatched
/// oracle meets — batching is only allowed to spend measured headroom (or
/// to group requests that were already predicted late).
#[test]
fn slack_policy_never_manufactures_misses() {
    let (none_probe, none_report) = bursty_run(BatchConfig::default());
    let (slack_probe, slack_report) = bursty_run(BatchConfig {
        policy: BatchPolicyKind::Slack,
        max: 4,
        wait_s: 4e-3,
    });
    // paired seeds: same offered population, everything admitted+completed
    assert_eq!(none_probe.completed, slack_probe.completed);
    assert!(none_report.batch.is_none());
    let b = slack_report.batch.expect("slack run reports batch stats");
    assert!(
        b.batched_dispatches > 0,
        "bursty mix formed no batches: {b:?}"
    );
    let manufactured: Vec<usize> = slack_probe
        .misses
        .difference(&none_probe.misses)
        .copied()
        .collect();
    assert!(
        manufactured.is_empty(),
        "slack batching manufactured misses for requests {manufactured:?} \
         (none missed {:?})",
        none_probe.misses
    );
}

/// The plan cache keyed on (model × condition × objective × batch bucket)
/// serves recurring regimes from cache in batched runs too.
#[test]
fn batched_plan_cache_hits_across_regime_changes() {
    let mut engine = Engine::new(EngineConfig {
        policy: PolicyKind::AdaOper,
        scheduler: SchedulerKind::Edf,
        duration_s: 0.6,
        seed: 31,
        calib: quick_calib(31),
        batching: BatchConfig {
            policy: BatchPolicyKind::Slack,
            max: 4,
            wait_s: 4e-3,
        },
        // coarse utilization quantization: the OU background wobble must
        // not split a recurring condition across buckets (the same choice
        // the cache scenario documents)
        plan_cache: adaoper::coordinator::PlanCacheConfig {
            util_bucket: 0.5,
            ..Default::default()
        },
        ..Default::default()
    });
    let stream = || {
        vec![StreamSpec::new(
            0,
            zoo::yolov2_tiny(),
            Arrival::Poisson { hz: 20.0 },
            0.8,
        )]
    };
    // moderate → high → moderate: the third run's initial planning must
    // find the moderate-bucket plan (keyed under batch bucket 3 = cap 4)
    engine.run(&stream()).unwrap();
    engine.apply_condition(&WorkloadCondition::high());
    engine.run(&stream()).unwrap();
    engine.apply_condition(&WorkloadCondition::moderate());
    let r = engine.run(&stream()).unwrap();
    let pc = r.plan_cache.expect("plan cache enabled by default");
    assert!(pc.hits >= 1, "no cache hits across recurring regimes: {pc:?}");
    assert!(pc.misses >= 2, "expected cold misses per condition: {pc:?}");
}
