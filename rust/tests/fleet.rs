//! Fleet-layer integration: the sharded runner must be bit-identical
//! across thread counts, and per-class tail latency must track device
//! capability (budget hardware is slower than flagship hardware).

use std::sync::OnceLock;

use adaoper::fleet::runner::{calibrate_classes, run_fleet_with};
use adaoper::fleet::{DeviceClass, FleetReport, FleetRunConfig};
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;

fn cfg(threads: usize) -> FleetRunConfig {
    FleetRunConfig {
        devices: 200,
        threads,
        seed: 42,
        duration_s: 1.0,
        calib: CalibConfig {
            samples: 900,
            seed: 42,
            gbdt: GbdtParams {
                trees: 25,
                ..Default::default()
            },
        },
        ..Default::default()
    }
}

/// The expensive part: calibrate each device class once (the immutable
/// per-class models the determinism contract shares), then run the same
/// 200-device fleet single-threaded and with 8 workers.
fn reports() -> &'static (FleetReport, FleetReport) {
    static R: OnceLock<(FleetReport, FleetReport)> = OnceLock::new();
    R.get_or_init(|| {
        let offline = calibrate_classes(&cfg(1).calib, &DeviceClass::all(), 3);
        (
            run_fleet_with(&cfg(1), &offline).unwrap(),
            run_fleet_with(&cfg(8), &offline).unwrap(),
        )
    })
}

#[test]
fn fleet_report_bit_identical_across_thread_counts() {
    let (a, b) = reports();
    // the rendered FleetReport is byte-identical …
    assert_eq!(a.render(), b.render());
    // … and so is the underlying merged state, down to float bits
    assert_eq!(a.fleet.offered, b.fleet.offered);
    assert_eq!(a.fleet.completed, b.fleet.completed);
    assert_eq!(a.fleet.shed, b.fleet.shed);
    assert_eq!(a.fleet.deadline_misses, b.fleet.deadline_misses);
    assert_eq!(
        a.fleet.total_energy_j.to_bits(),
        b.fleet.total_energy_j.to_bits()
    );
    for class in DeviceClass::all() {
        let (ca, cb) = (a.class(class), b.class(class));
        assert_eq!(ca.devices, cb.devices, "{}", class.name());
        assert_eq!(ca.completed, cb.completed, "{}", class.name());
        assert_eq!(ca.latency.counts(), cb.latency.counts(), "{}", class.name());
        assert_eq!(
            ca.total_energy_j.to_bits(),
            cb.total_energy_j.to_bits(),
            "{}",
            class.name()
        );
    }
}

#[test]
fn fleet_completes_work_across_all_classes() {
    let (a, _) = reports();
    assert!(a.fleet.completed > 100, "only {} completed", a.fleet.completed);
    for class in DeviceClass::all() {
        let agg = a.class(class);
        assert!(agg.devices > 0, "sampler starved class {}", class.name());
        assert!(agg.completed > 0, "class {} completed nothing", class.name());
    }
    // every device contributed exactly once
    let per_class_devices: usize = DeviceClass::all()
        .iter()
        .map(|&c| a.class(c).devices)
        .sum();
    assert_eq!(per_class_devices, 200);
    assert_eq!(a.fleet.devices, 200);
}

#[test]
fn budget_class_p95_at_least_flagship_p95() {
    let (a, _) = reports();
    let flagship = a.class(DeviceClass::Flagship);
    let budget = a.class(DeviceClass::Budget);
    let p95_flag = flagship.latency.quantile(0.95).unwrap();
    let p95_budget = budget.latency.quantile(0.95).unwrap();
    assert!(
        p95_budget >= p95_flag,
        "budget p95 {p95_budget} s < flagship p95 {p95_flag} s"
    );
    // the midrange tier sits no faster than flagship either
    let p95_mid = a.class(DeviceClass::MidRange).latency.quantile(0.95).unwrap();
    assert!(
        p95_mid >= p95_flag,
        "midrange p95 {p95_mid} s < flagship p95 {p95_flag} s"
    );
}
