//! Golden replay suite for the event-kernel refactor.
//!
//! The engine's `run` was re-expressed as five composable stages over the
//! discrete-event kernel (`rust/src/sim/`). Its correctness gate is
//! *bit-identical replay*: for fixed seeds, `ServingReport::row()` must
//! be byte-for-byte reproducible — across repeated runs (every virtual
//! time advance, PRNG split, and monitor-ordering decision is
//! deterministic, now that partitioning-decision time is virtualized) and
//! against the committed snapshot, across every scheduler × admission
//! combination plus the AdaOper drift path.
//!
//! Snapshot workflow: `tests/golden/serving_rows.txt` is compared when
//! present; when absent (first run on a fresh checkout) or when
//! `ADAOPER_UPDATE_GOLDEN=1` is set, the suite writes it from the current
//! engine and passes — commit the regenerated file with any intentional
//! behavior change.

use std::path::PathBuf;
use std::sync::OnceLock;

use adaoper::batching::BatchConfig;
use adaoper::config::schema::{BatchPolicyKind, PolicyKind, SchedulerKind};
use adaoper::coordinator::{AdmissionPolicy, Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::profiler::calibrate::{calibrate_on, CalibConfig, OfflineModel};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::profiler::{EnergyProfiler, EwmaCorrector};
use adaoper::soc::device::DeviceConfig;
use adaoper::workload::Arrival;

const SEED: u64 = 17;

fn calib() -> CalibConfig {
    CalibConfig {
        samples: 1200,
        seed: 5,
        gbdt: GbdtParams {
            trees: 40,
            ..Default::default()
        },
    }
}

/// One shared offline model: the GBDT fit is deterministic but expensive,
/// and sharing it is exactly what `Engine::with_profiler` exists for.
fn offline() -> &'static OfflineModel {
    static OFF: OnceLock<OfflineModel> = OnceLock::new();
    OFF.get_or_init(|| calibrate_on(&calib(), &DeviceConfig::snapdragon_855()))
}

fn streams() -> Vec<StreamSpec> {
    vec![
        StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 30.0 }, 0.25),
        StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 20.0 }, 0.4),
    ]
}

fn run_cell(policy: PolicyKind, scheduler: SchedulerKind, admission: AdmissionPolicy) -> String {
    run_cell_batched(policy, scheduler, admission, BatchConfig::default())
}

fn run_cell_batched(
    policy: PolicyKind,
    scheduler: SchedulerKind,
    admission: AdmissionPolicy,
    batching: BatchConfig,
) -> String {
    let profiler = EnergyProfiler::with_correctors(offline().clone(), || {
        Box::new(EwmaCorrector::default())
    });
    let mut engine = Engine::with_profiler(
        EngineConfig {
            policy,
            scheduler,
            admission,
            batching,
            duration_s: 1.2,
            seed: SEED,
            calib: calib(),
            ..Default::default()
        },
        profiler,
    );
    engine.run(&streams()).unwrap().row()
}

/// The full matrix: every scheduler × admit-all/drop-late under the
/// MaceGpu baseline (regime path only), plus two AdaOper cells that
/// exercise the drift fast path.
fn cells() -> Vec<(String, PolicyKind, SchedulerKind, AdmissionPolicy)> {
    let mut out = Vec::new();
    for sched in SchedulerKind::all() {
        for (name, adm) in [
            ("admit-all", AdmissionPolicy::AdmitAll),
            ("drop-late", AdmissionPolicy::DropLate),
        ] {
            out.push((
                format!("mace-gpu/{}/{}", sched.name(), name),
                PolicyKind::MaceGpu,
                sched,
                adm,
            ));
        }
    }
    out.push((
        "adaoper/fifo/admit-all".to_string(),
        PolicyKind::AdaOper,
        SchedulerKind::Fifo,
        AdmissionPolicy::AdmitAll,
    ));
    out.push((
        "adaoper/edf/drop-late".to_string(),
        PolicyKind::AdaOper,
        SchedulerKind::Edf,
        AdmissionPolicy::DropLate,
    ));
    out
}

fn render_all() -> String {
    let mut s = String::new();
    for (label, policy, sched, adm) in cells() {
        s.push_str(&label);
        s.push_str(": ");
        s.push_str(&run_cell(policy, sched, adm));
        s.push('\n');
    }
    s
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("serving_rows.txt")
}

#[test]
fn repeated_runs_are_byte_identical() {
    // two fresh engines per cell (shared immutable offline model): the
    // report row, including every formatted float, must match exactly
    for (label, policy, sched, adm) in cells() {
        let a = run_cell(policy, sched, adm);
        let b = run_cell(policy, sched, adm);
        assert_eq!(a, b, "cell {label} is not deterministic");
    }
}

#[test]
fn rows_match_golden_snapshot() {
    let got = render_all();
    let path = golden_path();
    compare_or_bootstrap(&got, &path);
}

fn compare_or_bootstrap(got: &str, path: &PathBuf) {
    let update = std::env::var("ADAOPER_UPDATE_GOLDEN").is_ok();
    if update || !path.exists() {
        std::fs::write(path, got).expect("write golden snapshot");
        eprintln!(
            "golden snapshot {} {} — commit it",
            path.display(),
            if update { "updated" } else { "bootstrapped" }
        );
        return;
    }
    let want = std::fs::read_to_string(path).expect("read golden snapshot");
    if got != want {
        for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
            assert_eq!(
                g,
                w,
                "first divergence at line {} (set ADAOPER_UPDATE_GOLDEN=1 to re-capture \
                 after an intentional behavior change)",
                i + 1
            );
        }
        assert_eq!(got.lines().count(), want.lines().count(), "line counts differ");
        panic!("golden rows differ only in line endings");
    }
}

/// The batching cells: fixed + slack formation riding the AdaOper drift
/// trace (the EDF/drop-late cell that exercises the drift fast path).
/// Snapshotted separately from the main matrix so the pre-batching rows
/// stay byte-identical to their own golden file.
fn batching_cells() -> Vec<(String, BatchConfig)> {
    let mk = |policy, max| BatchConfig {
        policy,
        max,
        wait_s: 4e-3,
    };
    vec![
        (
            "adaoper/edf/drop-late/batch-fixed4".to_string(),
            mk(BatchPolicyKind::Fixed, 4),
        ),
        (
            "adaoper/edf/drop-late/batch-slack4".to_string(),
            mk(BatchPolicyKind::Slack, 4),
        ),
    ]
}

fn render_batching() -> String {
    let mut s = String::new();
    for (label, batching) in batching_cells() {
        s.push_str(&label);
        s.push_str(": ");
        s.push_str(&run_cell_batched(
            PolicyKind::AdaOper,
            SchedulerKind::Edf,
            AdmissionPolicy::DropLate,
            batching,
        ));
        s.push('\n');
    }
    s
}

#[test]
fn batching_cells_are_deterministic_and_match_snapshot() {
    for (label, batching) in batching_cells() {
        let a = run_cell_batched(
            PolicyKind::AdaOper,
            SchedulerKind::Edf,
            AdmissionPolicy::DropLate,
            batching.clone(),
        );
        let b = run_cell_batched(
            PolicyKind::AdaOper,
            SchedulerKind::Edf,
            AdmissionPolicy::DropLate,
            batching,
        );
        assert_eq!(a, b, "batching cell {label} is not deterministic");
        assert!(a.contains("batch"), "cell {label} reported no batching: {a}");
    }
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("batching_rows.txt");
    compare_or_bootstrap(&render_batching(), &path);
}

#[test]
fn explicit_none_batching_matches_legacy_rows() {
    // an explicit `none` batch policy must leave every report row exactly
    // as the default (batching-free) engine renders it
    let legacy = run_cell(
        PolicyKind::MaceGpu,
        SchedulerKind::Edf,
        AdmissionPolicy::DropLate,
    );
    let none = run_cell_batched(
        PolicyKind::MaceGpu,
        SchedulerKind::Edf,
        AdmissionPolicy::DropLate,
        BatchConfig {
            policy: BatchPolicyKind::None,
            max: 16,
            wait_s: 0.5,
        },
    );
    assert_eq!(legacy, none, "batch-policy none must be byte-identical");
}
