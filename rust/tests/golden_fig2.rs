//! Golden snapshot of the Figure-2 reproduction output
//! (`experiments::fig2::render`) — the rendering layer behind
//! `cargo bench --bench fig2` / `ADAOPER_BENCH_QUICK=1` and the
//! `adaoper fig2` CLI. The snapshot pins the full report text (panel
//! layout, headline-delta derivation, paper-reference values) against a
//! deterministic synthetic row set, so the reproduction output cannot
//! silently drift. An opt-in end-to-end variant re-runs the real
//! quick-config matrix when `ADAOPER_BENCH_QUICK` is set.

use adaoper::config::schema::{ConditionKind, PolicyKind};
use adaoper::experiments::fig2::{render, run, Fig2Config, Fig2Row};
use adaoper::metrics::ServingReport;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::util::stats::Summary;

const GOLDEN: &str = include_str!("golden/fig2_render.txt");

fn summary(v: f64) -> Option<Summary> {
    Some(Summary {
        count: 40,
        mean: v,
        std: 0.0,
        min: v,
        max: v,
        p50: v,
        p90: v,
        p99: v,
    })
}

fn row(
    policy: PolicyKind,
    condition: ConditionKind,
    lat_mean_s: f64,
    inf_per_j: f64,
    cpu_util: f64,
) -> Fig2Row {
    Fig2Row {
        policy,
        condition,
        report: ServingReport {
            policy: policy.name().to_string(),
            condition: condition.name().to_string(),
            device: None,
            models: vec!["yolov2".to_string()],
            duration_s: 10.0,
            requests: 40,
            throughput_hz: 4.0,
            latency: summary(lat_mean_s),
            latency_hist: None,
            queue: None,
            miss_rate: 0.0,
            total_energy_j: 10.0,
            j_per_inference: 1.0 / inf_per_j,
            inferences_per_j: inf_per_j,
            avg_cpu_util: cpu_util,
            avg_gpu_util: 0.5,
            repartitions: 0,
            partition_overhead_s: 0.0,
            plan_cache: None,
            sched: None,
            batch: None,
            telemetry: None,
            health: None,
        },
    }
}

/// Deterministic synthetic matrix: binary-exact latencies/efficiencies so
/// every formatted number (including the derived AdaOper-vs-CoDL deltas) is
/// reproducible bit-for-bit across platforms.
fn synthetic_rows() -> Vec<Fig2Row> {
    vec![
        row(PolicyKind::MaceGpu, ConditionKind::Moderate, 0.25, 3.0, 0.5),
        row(PolicyKind::MaceGpu, ConditionKind::High, 0.5, 1.5, 0.5),
        row(PolicyKind::Codl, ConditionKind::Moderate, 0.125, 4.0, 0.5),
        row(PolicyKind::Codl, ConditionKind::High, 0.25, 2.0, 0.5),
        row(PolicyKind::AdaOper, ConditionKind::Moderate, 0.0625, 8.0, 0.75),
        row(PolicyKind::AdaOper, ConditionKind::High, 0.125, 4.0, 0.875),
    ]
}

#[test]
fn render_matches_golden_snapshot() {
    let got = render(&synthetic_rows());
    if got != GOLDEN {
        // line-by-line diff for an actionable failure message
        for (i, (g, w)) in got.lines().zip(GOLDEN.lines()).enumerate() {
            assert_eq!(g, w, "first divergence at line {}", i + 1);
        }
        assert_eq!(
            got.lines().count(),
            GOLDEN.lines().count(),
            "line counts differ"
        );
        panic!("render output differs from golden only in line endings");
    }
}

#[test]
fn golden_snapshot_derivations_are_consistent() {
    // the deltas in the golden file must equal what the synthetic rows
    // imply: AdaOper halves CoDL's latency (50.00%) and doubles its
    // efficiency (100.00%) in both conditions
    assert!(GOLDEN.contains("adaoper             62.50       125.00"));
    assert!(GOLDEN.contains("moderate           50.00% ( 3.94%)          100.00% ( 4.06%)"));
    assert!(GOLDEN.contains("high               50.00% (12.97%)          100.00% (16.88%)"));
    assert!(GOLDEN.contains("(paper-reported values in parentheses)"));
}

#[test]
fn render_of_empty_rows_keeps_headers() {
    let txt = render(&[]);
    assert!(txt.contains("panel (a)"));
    assert!(txt.contains("panel (b)"));
    assert!(txt.contains("AdaOper vs CoDL"));
}

/// Opt-in end-to-end run of the real quick-config matrix (the
/// `ADAOPER_BENCH_QUICK=1` path of `cargo bench --bench fig2`): structural
/// guards on the live output. Heavy, so it only runs when the env var is
/// set — exactly like the bench itself.
#[test]
fn quick_config_fig2_renders_all_sections_when_requested() {
    if std::env::var("ADAOPER_BENCH_QUICK").is_err() {
        eprintln!("skipping: set ADAOPER_BENCH_QUICK=1 to run the live quick-config check");
        return;
    }
    let cfg = Fig2Config {
        model: "yolov2".into(),
        n_requests: 15,
        seed: 7,
        calib: CalibConfig {
            samples: 2500,
            seed: 42,
            gbdt: GbdtParams {
                trees: 80,
                ..Default::default()
            },
        },
    };
    let rows = run(&cfg).unwrap();
    assert_eq!(rows.len(), 6);
    let txt = render(&rows);
    for needle in [
        "panel (a)",
        "panel (b)",
        "mace-gpu",
        "codl",
        "adaoper",
        "AdaOper vs CoDL",
        "measured average CPU utilization",
    ] {
        assert!(txt.contains(needle), "missing `{needle}` in:\n{txt}");
    }
    assert!(!txt.contains("NaN"), "live quick run produced NaN cells:\n{txt}");
}
