//! Golden snapshot for the Perfetto (Chrome trace-event) exporter: a
//! short fixed-seed telemetry run is recorded in-process, exported with
//! `perfetto::export_str`, and pinned byte-for-byte against
//! `tests/golden/perfetto_export.json`.
//!
//! Snapshot workflow matches `golden_determinism.rs`: the file is
//! compared when present; when absent (fresh checkout) or when
//! `ADAOPER_UPDATE_GOLDEN=1` is set, it is written from the current
//! exporter and the test passes — commit the regenerated file with any
//! intentional change to the trace schema or exporter.

use std::path::PathBuf;
use std::sync::OnceLock;

use adaoper::config::schema::{ConditionKind, PolicyKind, SchedulerKind};
use adaoper::coordinator::{AdmissionPolicy, Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::metrics::perfetto;
use adaoper::metrics::trace::{TraceMeta, TraceObserver};
use adaoper::profiler::calibrate::{calibrate_on, CalibConfig, OfflineModel};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::profiler::{EnergyProfiler, EwmaCorrector};
use adaoper::soc::device::DeviceConfig;
use adaoper::workload::Arrival;

const SEED: u64 = 17;

fn calib() -> CalibConfig {
    CalibConfig {
        samples: 1200,
        seed: 5,
        gbdt: GbdtParams {
            trees: 40,
            ..Default::default()
        },
    }
}

fn offline() -> &'static OfflineModel {
    static OFF: OnceLock<OfflineModel> = OnceLock::new();
    OFF.get_or_init(|| calibrate_on(&calib(), &DeviceConfig::snapdragon_855()))
}

fn streams() -> Vec<StreamSpec> {
    vec![
        StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 30.0 }, 0.25),
        StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 20.0 }, 0.4),
    ]
}

/// Short AdaOper run with telemetry + kernel events on and a regime
/// change at 0.5 s, so the export carries op spans on both processor
/// tracks plus monitor/plan instants.
fn config() -> EngineConfig {
    EngineConfig {
        policy: PolicyKind::AdaOper,
        scheduler: SchedulerKind::Edf,
        admission: AdmissionPolicy::DropLate,
        duration_s: 1.0,
        seed: SEED,
        calib: calib(),
        condition_timeline: vec![(0.5, ConditionKind::High)],
        telemetry: true,
        ..Default::default()
    }
}

/// Record the trace exactly the way `adaoper serve --telemetry --trace`
/// does: kernel events + request lines, then the audit decisions and the
/// report trailer.
fn record_trace() -> String {
    let ecfg = config();
    let profiler = EnergyProfiler::with_correctors(offline().clone(), || {
        Box::new(EwmaCorrector::default())
    });
    let mut engine = Engine::with_profiler(ecfg.clone(), profiler);
    let streams = streams();
    let mut trace = TraceObserver::with_meta(TraceMeta::of(&ecfg, &streams)).with_kernel_events();
    let report = engine.run_observed(&streams, &mut [&mut trace]).unwrap();
    if let Some(audit) = engine.audit() {
        for line in audit.jsonl_lines() {
            trace.push_line(line);
        }
    }
    trace.push_report_row(&report.row());
    trace.to_jsonl()
}

fn export() -> &'static String {
    static E: OnceLock<String> = OnceLock::new();
    E.get_or_init(|| perfetto::export_str(&record_trace()).expect("export fixed-seed trace"))
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/perfetto_export.json")
}

#[test]
fn export_matches_golden_snapshot() {
    let got = export();
    let path = golden_path();
    compare_or_bootstrap(got, &path);
}

#[test]
fn export_is_deterministic_and_valid() {
    // a second independent recording must serialize byte-identically
    let again = perfetto::export_str(&record_trace()).unwrap();
    assert_eq!(export(), &again, "perfetto export is not deterministic");

    // the export passes its own span-nesting validator with real spans
    let spans = perfetto::validate(export()).expect("span nesting");
    assert!(spans > 0, "export carries no complete op spans");

    // structural floor: both processor tracks are named, ops landed on
    // them, and the regime change at 0.5 s produced a plan-switch instant
    for meta in [
        r#""name":"cpu""#,
        r#""name":"gpu""#,
        r#""name":"monitor""#,
        r#""name":"plans""#,
    ] {
        assert!(export().contains(meta), "missing track meta {meta}");
    }
    assert!(export().contains(r#""cat":"op""#), "no op spans in export");
    assert!(
        export().contains("plan-switch"),
        "regime change produced no plan-switch instant"
    );
}

fn compare_or_bootstrap(got: &str, path: &PathBuf) {
    let update = std::env::var("ADAOPER_UPDATE_GOLDEN").is_ok();
    if update || !path.exists() {
        std::fs::write(path, got).expect("write golden snapshot");
        eprintln!(
            "golden snapshot {} {} — commit it",
            path.display(),
            if update { "updated" } else { "bootstrapped" }
        );
        return;
    }
    let want = std::fs::read_to_string(path).expect("read golden snapshot");
    if got != want {
        for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
            assert_eq!(
                g,
                w,
                "first divergence at line {} (set ADAOPER_UPDATE_GOLDEN=1 to re-capture \
                 after an intentional exporter/schema change)",
                i + 1
            );
        }
        assert_eq!(got.lines().count(), want.lines().count(), "line counts differ");
        panic!("golden export differs only in line endings");
    }
}
