//! Golden replay suite: recorded traces are regression tests.
//!
//! For each scheduler × admission cell, a serving run is captured as a
//! JSONL trace (header + request lines + report-row trailer) and
//! snapshotted under `tests/golden/`. The suite then replays the
//! *stored* trace through `adaoper::scenario::replay_str` — which
//! reconstructs the full `EngineConfig` from the header and feeds the
//! recorded arrivals back through the sim kernel — and asserts the
//! replayed `ServingReport::row()` equals the recorded one byte for
//! byte.
//!
//! Snapshot workflow matches `golden_determinism`: files are compared
//! when present, bootstrapped when absent (first run on a fresh
//! checkout), and regenerated under `ADAOPER_UPDATE_GOLDEN=1` — commit
//! regenerated traces with any intentional behavior change.

use std::path::PathBuf;
use std::sync::OnceLock;

use adaoper::config::schema::{PolicyKind, SchedulerKind};
use adaoper::coordinator::{AdmissionPolicy, Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::metrics::{TraceMeta, TraceObserver};
use adaoper::profiler::calibrate::{calibrate_on, CalibConfig, OfflineModel};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::profiler::{EnergyProfiler, EwmaCorrector};
use adaoper::scenario::replay_str;
use adaoper::soc::device::DeviceConfig;
use adaoper::workload::Arrival;

const SEED: u64 = 17;
const DURATION_S: f64 = 0.8;

fn calib() -> CalibConfig {
    CalibConfig { samples: 1200, seed: 5, gbdt: GbdtParams { trees: 40, ..Default::default() } }
}

/// Shared offline fit for the capture side (replay's `Engine::new`
/// refits from the header's calib block — deterministically the same
/// model, which is exactly what the suite verifies).
fn offline() -> &'static OfflineModel {
    static OFF: OnceLock<OfflineModel> = OnceLock::new();
    OFF.get_or_init(|| calibrate_on(&calib(), &DeviceConfig::snapdragon_855()))
}

fn streams() -> Vec<StreamSpec> {
    vec![
        StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 30.0 }, 0.25),
        StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 20.0 }, 0.4),
    ]
}

fn cells() -> Vec<(String, SchedulerKind, AdmissionPolicy)> {
    let mut out = Vec::new();
    for sched in SchedulerKind::all() {
        for (name, adm) in [
            ("admit-all", AdmissionPolicy::AdmitAll),
            ("drop-late", AdmissionPolicy::DropLate),
        ] {
            out.push((format!("{}_{}", sched.name(), name), sched, adm));
        }
    }
    out
}

/// Run one cell with trace recording on; returns the full JSONL text.
fn capture(scheduler: SchedulerKind, admission: AdmissionPolicy) -> String {
    let cfg = EngineConfig {
        policy: PolicyKind::MaceGpu,
        scheduler,
        admission,
        duration_s: DURATION_S,
        seed: SEED,
        calib: calib(),
        ..Default::default()
    };
    let profiler = EnergyProfiler::with_correctors(offline().clone(), || {
        Box::new(EwmaCorrector::default())
    });
    let strs = streams();
    let mut trace = TraceObserver::with_meta(TraceMeta::of(&cfg, &strs));
    let mut engine = Engine::with_profiler(cfg, profiler);
    let report = engine.run_observed(&strs, &mut [&mut trace]).unwrap();
    trace.push_report_row(&report.row());
    trace.to_jsonl()
}

fn golden_path(label: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join(format!("replay_{label}.jsonl"))
}

fn compare_or_bootstrap(got: &str, path: &PathBuf) -> String {
    let update = std::env::var("ADAOPER_UPDATE_GOLDEN").is_ok();
    if update || !path.exists() {
        std::fs::write(path, got).expect("write golden trace");
        eprintln!(
            "golden trace {} {} — commit it",
            path.display(),
            if update { "updated" } else { "bootstrapped" }
        );
        return got.to_string();
    }
    let want = std::fs::read_to_string(path).expect("read golden trace");
    assert_eq!(
        got, want,
        "captured trace {} diverged from snapshot (set ADAOPER_UPDATE_GOLDEN=1 to re-capture \
         after an intentional behavior change)",
        path.display()
    );
    want
}

#[test]
fn replay_reproduces_recorded_report_rows() {
    for (label, sched, adm) in cells() {
        let got = capture(sched, adm);
        let stored = compare_or_bootstrap(&got, &golden_path(&label));

        // replay the *stored* trace: reconstruct the config from its
        // header and feed the recorded arrivals back through the kernel
        let outcome = replay_str(&stored).unwrap_or_else(|e| panic!("cell {label}: {e:#}"));
        assert!(
            outcome.arrivals > 0,
            "cell {label}: trace carried no arrivals"
        );
        assert_eq!(
            outcome.matches(),
            Some(true),
            "cell {label}: replayed row diverged\n  recorded: {}\n  replayed: {}",
            outcome.recorded_row.as_deref().unwrap_or("<none>"),
            outcome.row
        );
    }
}

#[test]
fn replay_rejects_headerless_traces() {
    // legacy traces (TraceObserver::new) carry no header and must be
    // turned away with guidance, not a panic or a garbage run
    let err = replay_str("{\"id\":0,\"stream\":0,\"arrival_s\":0.1,\"deadline_s\":0.2,\"shed\":false}\n")
        .unwrap_err()
        .to_string();
    assert!(err.contains("trace_header"), "unexpected error: {err}");
}
