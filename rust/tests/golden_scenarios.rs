//! Golden wall over the declarative scenario corpus.
//!
//! The four ablation ports under `scenarios/` (batching burst, cache
//! recurrence, fleet scale, scheduler overload) already run in CI with
//! their `[expect]` bounds (`make scenarios`); this suite additionally
//! pins their **exact rendered rows** as refactor tripwires alongside the
//! serving/batching/replay goldens — a kernel change that shifts any
//! scenario's output by a single byte fails here before it reaches a
//! bound.
//!
//! Snapshot workflow matches `golden_determinism.rs`:
//! `tests/golden/scenario_rows.txt` is compared when present; when absent
//! or when `ADAOPER_UPDATE_GOLDEN=1` is set, it is (re)written from the
//! current kernel and must be committed.

use std::path::{Path, PathBuf};

use adaoper::scenario::runner::spec_files;
use adaoper::scenario::run_path;

fn corpus_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("scenarios")
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("scenario_rows.txt")
}

/// Run every spec in `scenarios/`, concatenating labeled rows.
fn render_corpus() -> String {
    let specs = spec_files(&corpus_dir()).expect("list scenario corpus");
    assert!(
        !specs.is_empty(),
        "scenario corpus is empty — nothing to pin"
    );
    let mut s = String::new();
    for path in specs {
        let outcome = run_path(&path).unwrap_or_else(|e| {
            panic!("scenario {} failed to run: {e:#}", path.display())
        });
        assert!(
            outcome.passed(),
            "scenario {} failed its [expect] bounds: {:?}",
            outcome.name,
            outcome.checks
        );
        s.push_str(&outcome.name);
        s.push_str(": ");
        s.push_str(&outcome.row);
        s.push('\n');
    }
    s
}

#[test]
fn scenario_corpus_matches_golden_rows() {
    let got = render_corpus();
    let path = golden_path();
    compare_or_bootstrap(&got, &path);
}

fn compare_or_bootstrap(got: &str, path: &Path) {
    let update = std::env::var("ADAOPER_UPDATE_GOLDEN").is_ok();
    if update || !path.exists() {
        std::fs::write(path, got).expect("write golden snapshot");
        eprintln!(
            "golden snapshot {} {} — commit it",
            path.display(),
            if update { "updated" } else { "bootstrapped" }
        );
        return;
    }
    let want = std::fs::read_to_string(path).expect("read golden snapshot");
    if got != want {
        for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
            assert_eq!(
                g,
                w,
                "first divergence at line {} (set ADAOPER_UPDATE_GOLDEN=1 to re-capture \
                 after an intentional behavior change)",
                i + 1
            );
        }
        assert_eq!(got.lines().count(), want.lines().count(), "line counts differ");
        panic!("golden rows differ only in line endings");
    }
}
