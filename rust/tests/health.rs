//! Health-layer integration: enabling the streaming health monitor must
//! only *append* to the report row (the health-off row is a byte-exact
//! prefix of the health-on row), the recorded alert stream must be
//! deterministic and survive trace replay byte-identically, fleet
//! alert rollups must be bit-identical across thread counts, and
//! `inspect`'s trace scanner must skip torn/garbage JSONL lines instead
//! of aborting.

use std::sync::OnceLock;

use adaoper::cli::commands::scan_trace;
use adaoper::config::schema::{ConditionKind, PolicyKind, SchedulerKind};
use adaoper::coordinator::{AdmissionPolicy, Engine, EngineConfig, StreamSpec};
use adaoper::fleet::runner::{calibrate_classes, run_fleet_with};
use adaoper::fleet::{DeviceClass, FleetReport, FleetRunConfig};
use adaoper::graph::zoo;
use adaoper::metrics::trace::{TraceMeta, TraceObserver};
use adaoper::metrics::{HealthConfig, ServingReport};
use adaoper::profiler::calibrate::{calibrate_on, CalibConfig, OfflineModel};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::profiler::{EnergyProfiler, EwmaCorrector};
use adaoper::scenario::replay_str;
use adaoper::soc::device::DeviceConfig;
use adaoper::workload::Arrival;

const SEED: u64 = 17;

fn calib() -> CalibConfig {
    CalibConfig {
        samples: 1200,
        seed: 5,
        gbdt: GbdtParams {
            trees: 40,
            ..Default::default()
        },
    }
}

fn offline() -> &'static OfflineModel {
    static OFF: OnceLock<OfflineModel> = OnceLock::new();
    OFF.get_or_init(|| calibrate_on(&calib(), &DeviceConfig::snapdragon_855()))
}

fn streams() -> Vec<StreamSpec> {
    vec![
        StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 30.0 }, 0.25),
        StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 20.0 }, 0.4),
    ]
}

/// An aggressive rule set: the drift trip is far below any realistic
/// windowed mean relative residual of the GBDT latency profile, so the
/// fixed-seed drift run is guaranteed to fire at least one drift alert.
fn tight_health() -> HealthConfig {
    HealthConfig {
        fast_window_s: 0.3,
        slow_window_s: 1.0,
        drift_warn: 1e-4,
        drift_critical: 1e3,
        min_samples: 3,
        ..HealthConfig::default()
    }
}

/// Fixed-seed AdaOper run with a mid-run regime change (the same fixture
/// `tests/telemetry.rs` pins for the audit log).
fn drift_config(health: Option<HealthConfig>) -> EngineConfig {
    EngineConfig {
        policy: PolicyKind::AdaOper,
        scheduler: SchedulerKind::Edf,
        admission: AdmissionPolicy::DropLate,
        duration_s: 1.2,
        seed: SEED,
        calib: calib(),
        condition_timeline: vec![(0.5, ConditionKind::High)],
        health,
        ..Default::default()
    }
}

fn run_drift(health: Option<HealthConfig>) -> ServingReport {
    let profiler = EnergyProfiler::with_correctors(offline().clone(), || {
        Box::new(EwmaCorrector::default())
    });
    let mut engine = Engine::with_profiler(drift_config(health), profiler);
    engine.run(&streams()).unwrap()
}

#[test]
fn health_off_row_is_byte_prefix_of_health_on_row() {
    let off = run_drift(None);
    let on = run_drift(Some(tight_health()));
    assert!(off.health.is_none());
    let summary = on.health.expect("health on ⇒ summary present");
    assert!(summary.ticks > 0, "run evaluated no monitor ticks");
    assert!(summary.alerts > 0, "aggressive drift trip fired no alert");
    assert!(summary.drift_alerts > 0, "no drift alert despite 1e-4 trip");

    let (row_off, row_on) = (off.row(), on.row());
    assert!(
        row_on.starts_with(&row_off),
        "health must only append:\n off: {row_off}\n on:  {row_on}"
    );
    assert!(row_on.contains("health "), "{row_on}");
}

/// Record the trace exactly the way `adaoper serve --trace --health`
/// does; alert lines ride the observer channel into the JSONL body.
fn record_trace() -> String {
    let ecfg = drift_config(Some(tight_health()));
    let profiler = EnergyProfiler::with_correctors(offline().clone(), || {
        Box::new(EwmaCorrector::default())
    });
    let mut engine = Engine::with_profiler(ecfg.clone(), profiler);
    let streams = streams();
    let mut trace = TraceObserver::with_meta(TraceMeta::of(&ecfg, &streams));
    let report = engine.run_observed(&streams, &mut [&mut trace]).unwrap();
    trace.push_report_row(&report.row());
    trace.to_jsonl()
}

fn alert_lines(jsonl: &str) -> Vec<&str> {
    jsonl.lines().filter(|l| l.contains("\"event\":\"alert\"")).collect()
}

#[test]
fn alert_stream_is_deterministic_and_replays_byte_identically() {
    let trace = record_trace();
    let alerts = alert_lines(&trace);
    assert!(!alerts.is_empty(), "drift run recorded no alert lines");

    // a second independent recording serializes the identical stream
    let again = record_trace();
    assert_eq!(alerts, alert_lines(&again), "alert stream is not deterministic");
    assert_eq!(trace, again, "trace body is not deterministic");

    // replay reconstructs the health config from the header and must
    // reproduce the recorded row — including the health section —
    // byte-for-byte
    let outcome = replay_str(&trace).unwrap();
    assert!(outcome.row.contains("health "), "{}", outcome.row);
    assert_eq!(
        outcome.matches(),
        Some(true),
        "replay row diverged\n  recorded: {:?}\n  replayed: {}",
        outcome.recorded_row,
        outcome.row
    );
}

fn fleet_cfg(threads: usize) -> FleetRunConfig {
    FleetRunConfig {
        devices: 12,
        threads,
        seed: 42,
        duration_s: 0.8,
        health: Some(tight_health()),
        calib: CalibConfig {
            samples: 900,
            seed: 42,
            gbdt: GbdtParams {
                trees: 25,
                ..Default::default()
            },
        },
        ..Default::default()
    }
}

fn fleet_reports() -> &'static (FleetReport, FleetReport) {
    static R: OnceLock<(FleetReport, FleetReport)> = OnceLock::new();
    R.get_or_init(|| {
        let offline = calibrate_classes(&fleet_cfg(1).calib, &DeviceClass::all(), 3);
        (
            run_fleet_with(&fleet_cfg(1), &offline).unwrap(),
            run_fleet_with(&fleet_cfg(8), &offline).unwrap(),
        )
    })
}

#[test]
fn fleet_alert_rollups_bit_identical_across_thread_counts() {
    let (a, b) = fleet_reports();
    // all-u64 sums merged in device order: exact for any thread count
    assert!(a.fleet.alerts > 0, "fleet run fired no alerts under a 1e-4 drift trip");
    assert_eq!(a.fleet.alerts, b.fleet.alerts);
    assert_eq!(a.fleet.warn_alerts, b.fleet.warn_alerts);
    assert_eq!(a.fleet.critical_alerts, b.fleet.critical_alerts);
    assert_eq!(a.fleet.drift_alerts, b.fleet.drift_alerts);
    // and the rendered report — including the health section — is
    // byte-identical
    assert_eq!(a.render(), b.render());
    assert!(a.render().contains("health alerts:"), "{}", a.render());
}

#[test]
fn inspect_scanner_skips_torn_lines_instead_of_aborting() {
    let trace = record_trace();
    let n_alerts = alert_lines(&trace).len();

    // corrupt the body the way a crashed writer does: a torn (truncated)
    // JSON line and a line of garbage, in the middle of valid lines
    let mut lines: Vec<String> = trace.lines().map(str::to_string).collect();
    let torn = lines.last().unwrap()[..10].to_string();
    lines.insert(2, torn);
    lines.insert(3, "%%% not json at all %%%".to_string());
    let corrupt = lines.join("\n");

    let scan = scan_trace(&corrupt).expect("scanner must not abort on torn lines");
    assert_eq!(scan.skipped, 2, "exactly the two injected lines are skipped");
    assert_eq!(scan.alerts.len(), n_alerts, "valid alert lines survive");
    assert!(scan.report_row.is_some(), "the report trailer survives");

    // the pristine trace scans clean
    let clean = scan_trace(&trace).unwrap();
    assert_eq!(clean.skipped, 0);
    assert_eq!(clean.alerts.len(), n_alerts);
}
