//! Integration: the serving engine end to end across policies, conditions
//! and stream mixes — conservation checks (all requests complete, energy
//! adds up) plus the closed-loop / open-loop relationship.

use adaoper::config::schema::{ConditionKind, PolicyKind};
use adaoper::coordinator::{Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::workload::Arrival;

fn quick_calib(seed: u64) -> CalibConfig {
    CalibConfig {
        samples: 1800,
        seed,
        gbdt: GbdtParams {
            trees: 50,
            ..Default::default()
        },
    }
}

#[test]
fn all_policies_serve_all_conditions() {
    for policy in [PolicyKind::MaceGpu, PolicyKind::Codl, PolicyKind::AdaOper] {
        for condition in [ConditionKind::Idle, ConditionKind::Moderate, ConditionKind::High] {
            let mut e = Engine::new(EngineConfig {
                policy,
                condition,
                duration_s: 1.5,
                seed: 9,
                calib: quick_calib(9),
                ..Default::default()
            });
            let streams = vec![StreamSpec::new(
                0,
                zoo::yolov2_tiny(),
                Arrival::Poisson { hz: 6.0 },
                0.5,
            )];
            let r = e.run(&streams).unwrap();
            assert!(r.requests > 0, "{policy:?}/{condition:?}: no requests");
            assert!(r.total_energy_j > 0.0);
            assert!(r.latency.unwrap().min > 0.0);
        }
    }
}

#[test]
fn open_loop_latency_at_least_closed_loop_service_time() {
    // queueing can only add latency: open-loop p50 ≥ closed-loop mean × 0.9
    let mk = |seed| EngineConfig {
        policy: PolicyKind::MaceGpu,
        condition: ConditionKind::Moderate,
        duration_s: 4.0,
        seed,
        calib: quick_calib(5),
        ..Default::default()
    };
    let spec = StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 8.0 }, 0.5);
    let closed = Engine::new(mk(5)).run_closed_loop(&spec, 20).unwrap();
    let open = Engine::new(mk(5)).run(&[spec]).unwrap();
    let c = closed.latency.unwrap().mean;
    let o = open.latency.unwrap().p50;
    assert!(o >= c * 0.9, "open p50 {o} < closed mean {c}");
}

#[test]
fn multi_stream_requests_all_complete_and_energy_positive() {
    let mut e = Engine::new(EngineConfig {
        policy: PolicyKind::AdaOper,
        condition: ConditionKind::Moderate,
        duration_s: 2.5,
        seed: 11,
        calib: quick_calib(11),
        ..Default::default()
    });
    let streams = vec![
        StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Periodic { hz: 8.0, jitter: 0.0 }, 0.5),
        StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 6.0 }, 0.4),
        StreamSpec::new(2, zoo::resnet18(), Arrival::Poisson { hz: 4.0 }, 0.4),
    ];
    let r = e.run(&streams).unwrap();
    // periodic 8 Hz over 2.5 s alone gives ≥ 19 requests
    assert!(r.requests >= 25, "only {} requests", r.requests);
    assert!(r.j_per_inference > 0.0);
    assert!(r.avg_cpu_util > 0.0 && r.avg_cpu_util <= 1.0);
    assert!(r.miss_rate <= 1.0);
}

#[test]
fn seeds_change_outcomes_but_structure_holds() {
    let run = |seed| {
        let mut e = Engine::new(EngineConfig {
            policy: PolicyKind::AdaOper,
            condition: ConditionKind::High,
            duration_s: 2.0,
            seed,
            calib: quick_calib(13),
            ..Default::default()
        });
        e.run(&[StreamSpec::new(
            0,
            zoo::yolov2_tiny(),
            Arrival::Poisson { hz: 6.0 },
            0.5,
        )])
        .unwrap()
    };
    let a = run(1);
    let b = run(2);
    assert_ne!(a.requests, 0);
    assert_ne!(b.requests, 0);
    // different seeds → different workload realizations
    assert!(
        (a.total_energy_j - b.total_energy_j).abs() > 1e-9
            || a.requests != b.requests
    );
}

#[test]
fn oracle_planner_not_worse_than_profiler_planner() {
    use adaoper::coordinator::engine::PlannerInfo;
    let run = |info| {
        let mut e = Engine::new(EngineConfig {
            policy: PolicyKind::AdaOper,
            condition: ConditionKind::High,
            seed: 17,
            planner_info: info,
            calib: quick_calib(17),
            ..Default::default()
        });
        let spec = StreamSpec::new(0, zoo::yolov2(), Arrival::Poisson { hz: 5.0 }, 0.5);
        e.run_closed_loop(&spec, 15).unwrap()
    };
    let oracle = run(PlannerInfo::Oracle);
    let prof = run(PlannerInfo::Profiler);
    let edp = |r: &adaoper::metrics::ServingReport| {
        r.j_per_inference * r.latency.as_ref().unwrap().mean
    };
    // The oracle sees the hidden state only at planning instants, while
    // bursts/drift keep moving — so it bounds the profiler only up to the
    // stochastic realization. Check the relationship loosely (the tight
    // comparison is ablation A1's job, under controlled traces).
    assert!(
        edp(&oracle) <= edp(&prof) * 1.35,
        "oracle EDP {} ≫ profiler EDP {}",
        edp(&oracle),
        edp(&prof)
    );
    assert!(edp(&oracle).is_finite() && edp(&prof).is_finite());
}
