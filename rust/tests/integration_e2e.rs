//! End-to-end integration: the full three-layer stack — AOT artifacts
//! (Pallas → JAX → HLO text) executed through the coordinator's worker
//! threads while the simulator accounts energy/latency and the AOT GRU
//! corrector feeds the profiler. Skips gracefully when `make artifacts`
//! hasn't run.

use std::path::PathBuf;

use adaoper::config::schema::{ConditionKind, PolicyKind};
use adaoper::coordinator::live::{ExecutorFactory, LiveSession};
use adaoper::coordinator::{Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::partition::dp::DpPartitioner;
use adaoper::partition::plan::Plan;
use adaoper::partition::{Objective, Partitioner};
use adaoper::profiler::calibrate::{calibrate, CalibConfig};
use adaoper::profiler::corrector::GruCorrector;
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::profiler::EnergyProfiler;
use adaoper::runtime::session::{gru_infer_fn, ArtifactExecutor};
use adaoper::soc::device::{Device, DeviceConfig};
use adaoper::soc::Placement;
use adaoper::workload::{Arrival, WorkloadCondition};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn canonical_input(g: &adaoper::graph::ModelGraph) -> Vec<f32> {
    let n = g.input_shape.elems() as usize;
    (0..n).map(|i| ((i % 97) as f32 - 48.0) / 97.0).collect()
}

#[test]
fn live_session_with_real_numerics_and_golden_check() {
    let Some(dir) = artifacts_dir() else { return };
    let g = zoo::tiny_exec();
    let mut device = Device::new(DeviceConfig::snapdragon_855());
    device.apply_condition(&WorkloadCondition::moderate().spec);
    let snap = device.snapshot();
    let plan = DpPartitioner::new(Objective::MinEdp)
        .partition(&g, &device, &snap)
        .unwrap();
    let d2 = dir.clone();
    let factory: ExecutorFactory =
        Box::new(move || Box::new(ArtifactExecutor::new(&d2).expect("artifacts")));
    let (report, output) =
        LiveSession::run(&g, &plan, &mut device, factory, 4, canonical_input(&g)).unwrap();
    assert_eq!(report.requests, 4);
    assert!(report.throughput_hz > 0.0);

    // golden values computed by JAX at export time must match
    let golden = std::fs::read_to_string(dir.join("golden.txt")).unwrap();
    for line in golden.lines().filter(|l| !l.starts_with('#') && !l.trim().is_empty()) {
        let mut it = line.split_whitespace();
        let idx: usize = it.next().unwrap().parse().unwrap();
        let want: f32 = it.next().unwrap().parse().unwrap();
        assert!(
            (output[idx] - want).abs() <= 1e-4 * (1.0 + want.abs()),
            "golden mismatch at {idx}"
        );
    }
}

#[test]
fn live_session_output_independent_of_placement() {
    // numerics must not depend on where the scheduler puts ops
    let Some(dir) = artifacts_dir() else { return };
    let g = zoo::tiny_exec();
    let mut run_with = |placements: Vec<Placement>| {
        let mut device = Device::new(DeviceConfig::snapdragon_855());
        device.apply_condition(&WorkloadCondition::moderate().spec);
        let plan = Plan {
            placements,
            predicted: Default::default(),
            policy: "test".into(),
        };
        let d2 = dir.clone();
        let factory: ExecutorFactory =
            Box::new(move || Box::new(ArtifactExecutor::new(&d2).expect("artifacts")));
        LiveSession::run(&g, &plan, &mut device, factory, 1, canonical_input(&g))
            .unwrap()
            .1
    };
    let gpu = run_with(vec![Placement::GPU; g.num_ops()]);
    let alt = run_with(
        (0..g.num_ops())
            .map(|i| if i % 2 == 0 { Placement::CPU } else { Placement::GPU })
            .collect(),
    );
    assert_eq!(gpu.len(), alt.len());
    for (a, b) in gpu.iter().zip(&alt) {
        assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()));
    }
}

#[test]
fn engine_with_gru_artifact_and_numerics_hook() {
    // the full loop: virtual-time engine + real GRU corrector + per-op
    // numerics hook executing the real HLO blocks for tiny-exec requests.
    let Some(dir) = artifacts_dir() else { return };
    let calib = CalibConfig {
        samples: 1800,
        seed: 23,
        gbdt: GbdtParams {
            trees: 50,
            ..Default::default()
        },
    };
    let offline = calibrate(&calib);
    let d2 = dir.clone();
    let profiler = EnergyProfiler::with_correctors(offline, || {
        let infer = gru_infer_fn(&d2, 8).expect("gru artifact");
        Box::new(GruCorrector::new(8, infer))
    });
    let mut engine = Engine::with_profiler(
        EngineConfig {
            policy: PolicyKind::AdaOper,
            condition: ConditionKind::Moderate,
            duration_s: 1.5,
            seed: 23,
            calib,
            ..Default::default()
        },
        profiler,
    );
    // numerics hook: execute the matching artifact per op, carrying tensor
    // state per request id
    let mut exec = ArtifactExecutor::new(&dir).unwrap();
    let g = zoo::tiny_exec();
    let input = canonical_input(&g);
    let mut states: std::collections::HashMap<usize, Vec<f32>> = Default::default();
    let counter = std::rc::Rc::new(std::cell::Cell::new(0usize));
    let c2 = counter.clone();
    engine.set_numerics_hook(Box::new(move |req, op| {
        use adaoper::coordinator::live::OpExecutor;
        let state = states.entry(req.id).or_insert_with(|| input.clone());
        *state = exec.execute("tiny-exec", &op.name, &[state.clone()])?;
        c2.set(c2.get() + 1);
        Ok(())
    }));
    let streams = vec![StreamSpec::new(
        0,
        zoo::tiny_exec(),
        Arrival::Periodic { hz: 10.0, jitter: 0.0 },
        0.5,
    )];
    let r = engine.run(&streams).unwrap();
    assert!(r.requests > 5);
    assert_eq!(counter.get(), r.requests * g.num_ops());
    assert_eq!(engine.profiler().corrector_name(), "gru");
}
