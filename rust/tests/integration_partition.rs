//! Integration: profiler-driven planning end to end — calibrate a real
//! GBDT pair, plan with each policy, and check the plans behave sanely
//! when evaluated against the ground-truth device.

use adaoper::config::schema::PolicyKind;
use adaoper::partition::baselines::by_policy;
use adaoper::partition::plan::{evaluate, Objective};
use adaoper::profiler::calibrate::{calibrate, CalibConfig};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::profiler::EnergyProfiler;
use adaoper::graph::zoo;
use adaoper::soc::device::{Device, DeviceConfig};
use adaoper::soc::{Placement, Proc};
use adaoper::workload::WorkloadCondition;

fn frozen(cond: WorkloadCondition) -> Device {
    let mut d = Device::new(DeviceConfig {
        noise_sigma: 0.0,
        drift_sigma: 0.0,
        ..DeviceConfig::snapdragon_855()
    });
    let mut c = cond.spec;
    c.cpu_bg_sigma = 0.0;
    c.cpu_burst = 0.0;
    c.gpu_bg_sigma = 0.0;
    c.gpu_burst = 0.0;
    c.drift_sigma = 0.0;
    d.apply_condition(&c);
    d
}

fn quick_profiler() -> EnergyProfiler {
    // full default budget: the planning-regret and CPU-shedding tests are
    // calibration-quality-sensitive at the high-condition corner
    EnergyProfiler::offline_only(calibrate(&CalibConfig {
        samples: 6000,
        seed: 42,
        gbdt: GbdtParams::default(),
    }))
}

#[test]
fn every_policy_produces_valid_plans_for_every_model() {
    let prof = quick_profiler();
    let d = frozen(WorkloadCondition::moderate());
    let snap = d.snapshot();
    for policy in PolicyKind::all() {
        let p = by_policy(policy, Objective::MinEdp);
        for name in zoo::names() {
            let g = zoo::by_name(name).unwrap();
            let plan = p.partition(&g, &prof, &snap).unwrap();
            assert_eq!(plan.placements.len(), g.num_ops(), "{policy:?}/{name}");
            assert!(
                plan.placements.iter().all(|pl| pl.is_valid()),
                "{policy:?}/{name}"
            );
            // evaluating against the device never NaNs/zeros
            let c = evaluate(&g, &plan.placements, &d, &snap);
            assert!(c.latency_s > 0.0 && c.latency_s.is_finite());
            assert!(c.energy_j > 0.0 && c.energy_j.is_finite());
        }
    }
}

#[test]
fn profiler_planned_dp_close_to_oracle_planned_dp() {
    // The gap between planning with the learned profiler and planning with
    // ground truth is the profiler's planning regret — it must be small
    // under calibrated (frozen) conditions.
    let prof = quick_profiler();
    let obj = Objective::MinEdp;
    for cond in [WorkloadCondition::moderate(), WorkloadCondition::high()] {
        let d = frozen(cond);
        let snap = d.snapshot();
        let g = zoo::yolov2();
        let dp = adaoper::partition::dp::DpPartitioner::new(obj);
        let plan_prof = dp.solve(&g, &prof, &snap).unwrap();
        let plan_oracle = dp.solve(&g, &d, &snap).unwrap();
        let c_prof = evaluate(&g, &plan_prof.placements, &d, &snap);
        let c_oracle = evaluate(&g, &plan_oracle.placements, &d, &snap);
        let regret = obj.score(c_prof.energy_j, c_prof.latency_s)
            / obj.score(c_oracle.energy_j, c_oracle.latency_s);
        assert!(
            regret < 1.15,
            "{}: planning regret {regret:.3} (> 15%)",
            d.condition_name()
        );
    }
}

#[test]
fn adaoper_avoids_cpu_under_high_condition() {
    // the paper's key insight, as a hard test: under the throttled/loaded
    // high condition the energy-aware plan sheds CPU co-execution relative
    // to moderate.
    let prof = quick_profiler();
    let dp = adaoper::partition::dp::DpPartitioner::new(Objective::MinEdp);
    let g = zoo::yolov2();

    let cpu_share = |cond: WorkloadCondition| {
        let d = frozen(cond);
        let plan = dp.solve(&g, &prof, &d.snapshot()).unwrap();
        plan.placements
            .iter()
            .map(|p| p.frac_on(Proc::Cpu))
            .sum::<f64>()
    };
    let moderate = cpu_share(WorkloadCondition::moderate());
    let high = cpu_share(WorkloadCondition::high());
    assert!(
        high < moderate,
        "CPU share should drop under high: moderate {moderate:.2} vs high {high:.2}"
    );
}

#[test]
fn codl_beats_gpu_latency_but_not_energy_moderate() {
    // CoDL's defining behaviour in the evaluation.
    let d = frozen(WorkloadCondition::moderate());
    let snap = d.snapshot();
    let g = zoo::yolov2();
    let codl = by_policy(PolicyKind::Codl, Objective::MinEdp)
        .partition(&g, &d, &snap)
        .unwrap();
    let c = evaluate(&g, &codl.placements, &d, &snap);
    let gpu = evaluate(&g, &vec![Placement::GPU; g.num_ops()], &d, &snap);
    assert!(c.latency_s < gpu.latency_s, "codl no faster than GPU");
    assert!(c.energy_j > gpu.energy_j, "codl should pay energy for speed");
}
