//! Integration: the partition-plan cache end to end — cold-miss/warm-hit
//! behaviour, LRU eviction across real workload conditions, byte-identical
//! plans between the cached and freshly-computed paths on a frozen device,
//! and the headline hit rate on the bursty recurring-condition trace.

use adaoper::coordinator::plan_cache::{PlanCache, PlanCacheConfig};
use adaoper::experiments::cache_scenario::{self, CacheScenarioConfig};
use adaoper::graph::zoo;
use adaoper::partition::dp::DpPartitioner;
use adaoper::partition::plan::Objective;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::soc::device::{Device, DeviceConfig};
use adaoper::workload::WorkloadCondition;

fn frozen(cond: WorkloadCondition, seed: u64) -> Device {
    let mut d = Device::new(DeviceConfig {
        noise_sigma: 0.0,
        drift_sigma: 0.0,
        seed,
        ..DeviceConfig::snapdragon_855()
    });
    let mut c = cond.spec;
    c.cpu_bg_sigma = 0.0;
    c.cpu_burst = 0.0;
    c.gpu_bg_sigma = 0.0;
    c.gpu_burst = 0.0;
    c.drift_sigma = 0.0;
    d.apply_condition(&c);
    d
}

#[test]
fn cold_miss_warm_hit_and_byte_identical_plan_on_frozen_device() {
    let d = frozen(WorkloadCondition::moderate(), 3);
    let snap = d.snapshot();
    let g = zoo::yolov2_tiny();
    let dp = DpPartitioner::new(Objective::MinEdp);
    let mut cache = PlanCache::new(PlanCacheConfig::default());

    // cold miss
    assert!(cache.lookup(&g.name, &snap, Objective::MinEdp, 1).is_none());
    let solved = dp.solve(&g, &d, &snap).unwrap();
    cache.insert(&g.name, &snap, Objective::MinEdp, 1, solved.clone());

    // warm hit on the repeated condition
    let cached = cache.lookup(&g.name, &snap, Objective::MinEdp, 1).unwrap();
    assert_eq!(cached.placements, solved.placements);

    // the device is frozen, so a fresh DP solve is bit-for-bit reproducible
    // and the cached plan must match it exactly
    let fresh = dp.solve(&g, &d, &snap).unwrap();
    assert_eq!(cached.placements, fresh.placements);
    assert_eq!(
        cached.predicted.energy_j.to_bits(),
        fresh.predicted.energy_j.to_bits(),
        "cached energy prediction drifted from a fresh solve"
    );
    assert_eq!(
        cached.predicted.latency_s.to_bits(),
        fresh.predicted.latency_s.to_bits(),
        "cached latency prediction drifted from a fresh solve"
    );

    let st = cache.stats();
    assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1), "{st:?}");
}

#[test]
fn lru_eviction_across_real_conditions_at_capacity() {
    let g = zoo::yolov2_tiny();
    let dp = DpPartitioner::new(Objective::MinEdp);
    let mut cache = PlanCache::new(PlanCacheConfig {
        capacity: 2,
        ..Default::default()
    });
    // three conditions with distinct pinned/free-running frequencies →
    // three distinct buckets through a capacity-2 cache
    let conditions = [
        WorkloadCondition::moderate(),
        WorkloadCondition::high(),
        WorkloadCondition::idle(),
    ];
    for cond in &conditions {
        let d = frozen(cond.clone(), 1);
        let snap = d.snapshot();
        assert!(
            cache.lookup(&g.name, &snap, Objective::MinEdp, 1).is_none(),
            "{}: unexpected warm entry",
            cond.name()
        );
        let plan = dp.solve(&g, &d, &snap).unwrap();
        cache.insert(&g.name, &snap, Objective::MinEdp, 1, plan);
    }
    let st = cache.stats();
    assert_eq!(st.entries, 2, "{st:?}");
    assert_eq!(st.evictions, 1, "{st:?}");
    // the oldest condition (moderate) was evicted, the two recent ones hit
    let d = frozen(WorkloadCondition::moderate(), 1);
    assert!(cache.lookup(&g.name, &d.snapshot(), Objective::MinEdp, 1).is_none());
    let d = frozen(WorkloadCondition::high(), 1);
    assert!(cache.lookup(&g.name, &d.snapshot(), Objective::MinEdp, 1).is_some());
    let d = frozen(WorkloadCondition::idle(), 1);
    assert!(cache.lookup(&g.name, &d.snapshot(), Objective::MinEdp, 1).is_some());
}

#[test]
fn bursty_recurring_condition_trace_hit_rate_at_least_80_percent() {
    // the PR's acceptance scenario: two app streams, the device bouncing
    // between moderate and high — after the first cycle every repartition
    // should reuse a cached plan
    let res = cache_scenario::run(&CacheScenarioConfig {
        cycles: 10,
        requests_per_phase: 2,
        seed: 7,
        calib: CalibConfig {
            samples: 1800,
            seed: 7,
            gbdt: GbdtParams {
                trees: 50,
                ..Default::default()
            },
        },
        ..Default::default()
    })
    .unwrap();
    let st = res.stats;
    assert!(st.hits > 0 && st.misses > 0, "{st:?}");
    assert!(
        res.hit_rate() >= 0.8,
        "plan-cache hit rate {:.3} below 80% ({st:?})",
        res.hit_rate()
    );
    // counters must be visible through the metrics-report path
    assert!(st.lookups() >= 40, "{st:?}");
}
