//! Integration: the full profiler loop (calibrate → predict → observe →
//! correct → drift-trigger) against the live simulator, including failure
//! injection on the corrector path.

use adaoper::graph::zoo;
use adaoper::profiler::calibrate::{calibrate, CalibConfig};
use adaoper::profiler::corrector::{EwmaCorrector, GruCorrector};
use adaoper::profiler::monitor::ResourceMonitor;
use adaoper::profiler::{CostModel, EnergyProfiler};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::soc::device::{Device, DeviceConfig, ExecCtx};
use adaoper::soc::Placement;
use adaoper::workload::WorkloadCondition;

fn quick_calib() -> CalibConfig {
    CalibConfig {
        samples: 2500,
        seed: 42,
        gbdt: GbdtParams {
            trees: 80,
            ..Default::default()
        },
    }
}

/// Run ops through a live (bursty, drifting) device and return the mean
/// absolute relative energy error of the given profiler.
fn live_error(mut prof: EnergyProfiler, seed: u64) -> f64 {
    let mut d = Device::new(DeviceConfig {
        seed,
        ..DeviceConfig::snapdragon_855()
    });
    d.apply_condition(&WorkloadCondition::high().spec);
    let g = zoo::yolov2();
    let mut errs = Vec::new();
    for i in 0..400 {
        let op = &g.ops[i % g.num_ops()];
        let mut ctx = ExecCtx::fresh(vec![0.0; op.in_shapes.len()]);
        ctx.new_run_cpu = false;
        ctx.new_run_gpu = false;
        let snap = d.snapshot();
        let pred = prof.predict(op, Placement::GPU, &ctx, &snap);
        let truth = d.measure(op, Placement::GPU, &ctx);
        errs.push(((pred.energy_j - truth.energy_j) / truth.energy_j).abs());
        prof.observe(op, Placement::GPU, &ctx, &snap, &truth);
        d.advance(truth.latency_s, 0.0, 1.0);
    }
    errs.iter().sum::<f64>() / errs.len() as f64
}

#[test]
fn runtime_correction_reduces_live_error() {
    let offline = calibrate(&quick_calib());
    let static_err = live_error(EnergyProfiler::offline_only(offline.clone()), 99);
    let corrected_err = live_error(
        EnergyProfiler::with_correctors(offline, || Box::new(EwmaCorrector::default())),
        99,
    );
    assert!(
        corrected_err < static_err,
        "corrected {corrected_err:.4} ≥ static {static_err:.4}"
    );
}

#[test]
fn gru_corrector_with_failing_backend_degrades_gracefully() {
    // failure injection: the GRU inference backend dies after 5 calls —
    // the corrector must keep serving (stale factor) without panicking,
    // and the profiler must remain usable.
    let offline = calibrate(&quick_calib());
    let mut prof = EnergyProfiler::with_correctors(offline, || {
        let mut calls = 0;
        Box::new(GruCorrector::new(
            4,
            Box::new(move |_w| {
                calls += 1;
                if calls > 5 {
                    anyhow::bail!("backend gone");
                }
                Ok(0.1)
            }),
        ))
    });
    let err = live_error_with(&mut prof, 7);
    assert!(err.is_finite());
}

fn live_error_with(prof: &mut EnergyProfiler, seed: u64) -> f64 {
    let mut d = Device::new(DeviceConfig {
        seed,
        ..DeviceConfig::snapdragon_855()
    });
    d.apply_condition(&WorkloadCondition::moderate().spec);
    let g = zoo::yolov2_tiny();
    let mut errs = Vec::new();
    for i in 0..120 {
        let op = &g.ops[i % g.num_ops()];
        let ctx = ExecCtx::fresh(vec![0.0; op.in_shapes.len()]);
        let snap = d.snapshot();
        let pred = prof.predict(op, Placement::GPU, &ctx, &snap);
        let truth = d.measure(op, Placement::GPU, &ctx);
        errs.push(((pred.energy_j - truth.energy_j) / truth.energy_j).abs());
        prof.observe(op, Placement::GPU, &ctx, &snap, &truth);
        d.advance(truth.latency_s, 0.0, 1.0);
    }
    errs.iter().sum::<f64>() / errs.len() as f64
}

#[test]
fn monitor_flags_condition_switch_on_live_device() {
    let mut d = Device::new(DeviceConfig::snapdragon_855());
    d.apply_condition(&WorkloadCondition::moderate().spec);
    let mut mon = ResourceMonitor::default();
    for _ in 0..50 {
        d.advance(0.05, 0.2, 0.5);
        mon.sample(d.snapshot());
    }
    assert!(!mon.regime_changed());
    d.apply_condition(&WorkloadCondition::high().spec);
    d.advance(0.05, 0.2, 0.5);
    mon.sample(d.snapshot());
    assert!(mon.regime_changed(), "switch to high not detected");
}

#[test]
fn drift_trigger_fires_on_regime_change_without_reset() {
    // if nobody resets the corrector, a regime change must show up as
    // drift within a handful of observations
    let offline = calibrate(&quick_calib());
    let mut prof =
        EnergyProfiler::with_correctors(offline, || Box::new(EwmaCorrector::new(0.05)));
    let g = zoo::yolov2();
    let mut d = Device::new(DeviceConfig {
        seed: 3,
        ..DeviceConfig::snapdragon_855()
    });
    d.apply_condition(&WorkloadCondition::moderate().spec);
    // settle
    for i in 0..100 {
        let op = &g.ops[i % g.num_ops()];
        let ctx = ExecCtx::fresh(vec![0.0; op.in_shapes.len()]);
        let snap = d.snapshot();
        let truth = d.measure(op, Placement::GPU, &ctx);
        prof.observe(op, Placement::GPU, &ctx, &snap, &truth);
        d.advance(truth.latency_s, 0.0, 1.0);
    }
    // regime change: CPU/GPU repinned → GBDT inputs shift but the *frozen*
    // snapshot we keep feeding makes predictions stale → drift
    let stale_snap = d.snapshot();
    d.apply_condition(&WorkloadCondition::high().spec);
    let mut fired = false;
    for i in 0..60 {
        let op = &g.ops[i % g.num_ops()];
        let ctx = ExecCtx::fresh(vec![0.0; op.in_shapes.len()]);
        let truth = d.measure(op, Placement::GPU, &ctx);
        prof.observe(op, Placement::GPU, &ctx, &stale_snap, &truth);
        d.advance(truth.latency_s, 0.0, 1.0);
        if prof.drifted() {
            fired = true;
            break;
        }
    }
    assert!(fired, "drift never fired after regime change");
}
