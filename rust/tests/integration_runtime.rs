//! PJRT runtime integration: load the real AOT artifacts, execute them,
//! and verify numerics against invariants of the exported model. Tests
//! skip gracefully when `artifacts/` has not been built (`make artifacts`).

use std::path::PathBuf;

use adaoper::coordinator::live::OpExecutor;
use adaoper::runtime::session::{gru_infer_fn, ArtifactExecutor};
use adaoper::runtime::{Manifest, Runtime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn manifest_lists_all_blocks() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    for op in ["conv1", "pool1", "conv2", "pool2", "conv3", "pool3", "conv4", "conv5"] {
        assert!(m.get(&format!("tiny-exec/{op}")).is_some(), "missing {op}");
    }
    assert!(m.get("tiny-exec/full").is_some());
    assert!(m.get("gru/predict").is_some());
}

#[test]
fn full_model_executes_and_matches_block_chain() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let n_in = rt.manifest.get("tiny-exec/full").unwrap().in_elems();

    // deterministic pseudo-input
    let input: Vec<f32> = (0..n_in).map(|i| ((i % 97) as f32 - 48.0) / 97.0).collect();

    let full = rt.run_f32("tiny-exec/full", &input).unwrap();
    assert!(full.iter().all(|x| x.is_finite()));

    // chain the per-op artifacts: must reproduce the fused model exactly
    let mut x = input;
    for op in ["conv1", "pool1", "conv2", "pool2", "conv3", "pool3", "conv4", "conv5"] {
        x = rt.run_f32(&format!("tiny-exec/{op}"), &x).unwrap();
    }
    assert_eq!(x.len(), full.len());
    for (i, (a, b)) in x.iter().zip(&full).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
            "mismatch at {i}: {a} vs {b}"
        );
    }
}

#[test]
fn conv_block_output_is_sane() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let e = rt.manifest.get("tiny-exec/conv1").unwrap().clone();
    let input = vec![0.5f32; e.in_elems()];
    let out = rt.run_f32("tiny-exec/conv1", &input).unwrap();
    // random-weight conv of a constant field: finite, both signs present
    assert!(out.iter().all(|x| x.is_finite()));
    assert!(out.iter().any(|&x| x > 0.0));
    assert!(out.iter().any(|&x| x < 0.0));
}

#[test]
fn pool_halves_spatial_dims() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let e = rt.manifest.get("tiny-exec/pool1").unwrap().clone();
    assert_eq!(e.in_shape[2], 2 * e.out_shape[2]);
    // max pool over a constant field is the constant
    let input = vec![2.5f32; e.in_elems()];
    let out = rt.run_f32("tiny-exec/pool1", &input).unwrap();
    assert!(out.iter().all(|&x| (x - 2.5).abs() < 1e-6));
}

#[test]
fn wrong_input_size_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    assert!(rt.run_f32("tiny-exec/conv1", &[1.0, 2.0]).is_err());
    assert!(rt.run_f32("no-such-artifact", &[1.0]).is_err());
}

#[test]
fn artifact_executor_runs_ops() {
    let Some(dir) = artifacts_dir() else { return };
    let mut ex = ArtifactExecutor::new(&dir).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let e = m.get("tiny-exec/conv1").unwrap();
    let out = ex
        .execute("tiny-exec", "conv1", &[vec![0.1f32; e.in_elems()]])
        .unwrap();
    assert_eq!(out.len(), e.out_elems());
}

#[test]
fn gru_artifact_infers() {
    let Some(dir) = artifacts_dir() else { return };
    let mut f = gru_infer_fn(&dir, 8).unwrap();
    // constant positive residual window → prediction should move positive
    let mut window = vec![0.0f32; 8 * 4];
    for t in 0..8 {
        window[t * 4] = 0.3; // log-residual feature
        window[t * 4 + 1] = 0.4; // cpu util
        window[t * 4 + 2] = 0.1; // gpu util
        window[t * 4 + 3] = 0.45; // temp
    }
    let pred = f(&window).unwrap();
    assert!(pred.is_finite());
    assert!(pred > 0.0, "expected positive correction, got {pred}");
    // zero-residual window → smaller-magnitude prediction
    let zero = vec![0.0f32; 8 * 4];
    let p0 = f(&zero).unwrap();
    assert!(p0.abs() < pred.abs());
    // rejects bad window sizes
    assert!(f(&[0.0; 3]).is_err());
}

#[test]
fn gru_corrector_with_real_artifact_tracks_bias() {
    use adaoper::profiler::corrector::{Corrector, GruCorrector};
    let Some(dir) = artifacts_dir() else { return };
    let infer = gru_infer_fn(&dir, 8).unwrap();
    let mut c = GruCorrector::new(8, infer);
    let snap = adaoper::soc::device::Snapshot {
        time_s: 0.0,
        cpu_freq_hz: 1.49e9,
        gpu_freq_hz: 499e6,
        cpu_util: 0.4,
        gpu_util: 0.1,
        temp_c: 45.0,
        bw_factor: 0.9,
    };
    for _ in 0..20 {
        c.observe(0.25, &snap);
    }
    let f = c.factor();
    assert!(
        f > 1.02 && f < 1.6,
        "correction factor {f} should move toward e^0.25 ≈ 1.28"
    );
}

#[test]
fn cross_language_golden_values_match() {
    // Replays python's canonical input through the rust-loaded artifacts
    // and compares against values computed by JAX at export time. This is
    // the guard that caught the elided-constant corruption bug.
    let Some(dir) = artifacts_dir() else { return };
    let golden_path = dir.join("golden.txt");
    if !golden_path.exists() {
        eprintln!("skipping: golden.txt not present (older artifacts)");
        return;
    }
    let mut rt = Runtime::new(&dir).unwrap();
    let n_in = rt.manifest.get("tiny-exec/full").unwrap().in_elems();
    let input: Vec<f32> = (0..n_in).map(|i| ((i % 97) as f32 - 48.0) / 97.0).collect();
    let out = rt.run_f32("tiny-exec/full", &input).unwrap();
    let text = std::fs::read_to_string(&golden_path).unwrap();
    let mut checked = 0;
    for line in text.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let idx: usize = parts.next().unwrap().parse().unwrap();
        let want: f32 = parts.next().unwrap().parse().unwrap();
        let got = out[idx];
        assert!(
            (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
            "golden mismatch at {idx}: rust {got} vs jax {want}"
        );
        checked += 1;
    }
    assert!(checked >= 32, "golden file too small: {checked}");
}
