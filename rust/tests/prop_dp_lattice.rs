//! Differential suite: the flattened-lattice DP core vs the rolling
//! `BTreeMap` reference solver ([`MapDpPartitioner`]), driven in lockstep
//! over random graphs, frozen device snapshots, every objective family,
//! both candidate grids, both bucket widths, and randomly pinned windows
//! (including empty ones). Placements AND all four predicted `PlanCost`
//! fields must match **bit for bit** — the lattice backend is a speed
//! optimization, never a behavior change — both with the raw device model
//! (no [`CostModel::version`] → memo disabled) and through a versioned
//! wrapper that turns the per-column predict memo on.

use adaoper::experiments::ablations::random_chain;
use adaoper::graph::{zoo, ModelGraph, OpNode};
use adaoper::partition::dp::{DpBackend, DpPartitioner, MapDpPartitioner};
use adaoper::partition::plan::{Objective, PlanCost};
use adaoper::profiler::CostModel;
use adaoper::soc::device::{Device, DeviceConfig, ExecCtx, OpCost, Snapshot};
use adaoper::soc::Placement;
use adaoper::util::Prng;
use adaoper::workload::WorkloadCondition;

fn frozen(cond: WorkloadCondition, seed: u64) -> Device {
    let mut d = Device::new(DeviceConfig {
        noise_sigma: 0.0,
        drift_sigma: 0.0,
        seed,
        ..DeviceConfig::snapdragon_855()
    });
    let mut c = cond.spec;
    c.cpu_bg_sigma = 0.0;
    c.cpu_burst = 0.0;
    c.gpu_bg_sigma = 0.0;
    c.gpu_burst = 0.0;
    c.drift_sigma = 0.0;
    d.apply_condition(&c);
    d
}

/// Wrapper that opts into prediction memoization ([`CostModel::version`])
/// without changing any prediction — exercises the lattice solver's
/// per-column predict memo, which the raw `Device` (version = `None`)
/// never enters.
struct MemoDevice<'a>(&'a Device);

impl CostModel for MemoDevice<'_> {
    fn predict(
        &self,
        op: &OpNode,
        placement: Placement,
        ctx: &ExecCtx,
        snap: &Snapshot,
    ) -> OpCost {
        CostModel::predict(self.0, op, placement, ctx, snap)
    }

    fn version(&self) -> Option<u64> {
        Some(7)
    }
}

fn assert_cost_bits(a: &PlanCost, b: &PlanCost, what: &str) {
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{what}: energy_j");
    assert_eq!(a.latency_s.to_bits(), b.latency_s.to_bits(), "{what}: latency_s");
    assert_eq!(a.transfer_s.to_bits(), b.transfer_s.to_bits(), "{what}: transfer_s");
    assert_eq!(a.transfer_j.to_bits(), b.transfer_j.to_bits(), "{what}: transfer_j");
}

fn random_graph(rng: &mut Prng) -> ModelGraph {
    match rng.below(5) {
        0 => zoo::yolov2(),
        1 => zoo::yolov2_tiny(),
        2 => zoo::resnet18(),
        3 => zoo::mobilenet_v1(),
        _ => random_chain(6 + rng.below(7), rng.next_u64()),
    }
}

fn random_objective(rng: &mut Prng) -> Objective {
    match rng.below(3) {
        0 => Objective::MinEdp,
        1 => Objective::MinLatency,
        _ => Objective::MinEnergyUnderSlo {
            slo_s: 0.002 * (1 + rng.below(250)) as f64,
        },
    }
}

fn random_solver(rng: &mut Prng) -> DpPartitioner {
    let mut dp = DpPartitioner::new(random_objective(rng));
    if rng.chance(0.5) {
        dp = dp.with_choices(vec![Placement::CPU, Placement::GPU]);
    }
    dp.with_buckets(if rng.chance(0.5) { 4 } else { 64 })
}

/// Full-model solves: lattice == map, bit for bit, with and without the
/// predict memo engaged.
#[test]
fn full_solves_are_bit_identical_across_backends() {
    for seed in 0..5u64 {
        let mut rng = Prng::new(0x1A77_1CE0 ^ seed);
        for trial in 0..3 {
            let g = random_graph(&mut rng);
            let cond = if rng.chance(0.5) {
                WorkloadCondition::moderate()
            } else {
                WorkloadCondition::high()
            };
            let d = frozen(cond, rng.next_u64());
            let snap = d.snapshot();
            let lat = random_solver(&mut rng);
            let map = lat.clone().with_backend(DpBackend::Map);
            let tag = format!("seed {seed} trial {trial} {}", g.name);

            let a = lat.solve(&g, &d, &snap).unwrap();
            let b = map.solve(&g, &d, &snap).unwrap();
            assert_eq!(a.placements, b.placements, "{tag}: plain model");
            assert_cost_bits(&a.predicted, &b.predicted, &tag);

            // memoized path must change nothing — vs the map oracle AND
            // vs the lattice's own un-memoized run
            let memo = MemoDevice(&d);
            let m = lat.solve(&g, &memo, &snap).unwrap();
            assert_eq!(a.placements, m.placements, "{tag}: memo model");
            assert_cost_bits(&a.predicted, &m.predicted, &tag);
        }
    }
}

/// Windowed solves with pinned prefix/suffix and optional pre-window GPU
/// residency: lattice == map on every window, including empty ones.
#[test]
fn pinned_window_solves_are_bit_identical_across_backends() {
    for seed in 0..5u64 {
        let mut rng = Prng::new(0xD1FF_0000 ^ seed);
        let g = random_graph(&mut rng);
        let n = g.num_ops();
        let d = frozen(
            if seed % 2 == 0 {
                WorkloadCondition::moderate()
            } else {
                WorkloadCondition::high()
            },
            rng.next_u64(),
        );
        let snap = d.snapshot();
        let pinned: Vec<Placement> = (0..n)
            .map(|_| match rng.below(3) {
                0 => Placement::CPU,
                1 => Placement::GPU,
                _ => Placement::Split { cpu_frac: 0.15 },
            })
            .collect();
        let residency: Vec<f64> = (0..n).map(|_| rng.below(3) as f64 * 0.5).collect();
        let lat = random_solver(&mut rng);
        let map = MapDpPartitioner(lat.clone().with_backend(DpBackend::Map));
        // random windows plus the degenerate edges
        let mut windows = vec![(0, n), (n, n), (n / 2, n / 2)];
        for _ in 0..4 {
            let start = rng.below(n + 1);
            let end = start + rng.below(n - start + 1);
            windows.push((start, end));
        }
        for (start, end) in windows {
            for prev in [None, Some(&residency[..])] {
                let a = lat
                    .solve_range(&g, &d, &snap, start, end, &pinned, prev)
                    .unwrap();
                let b = map
                    .solve_range(&g, &d, &snap, start, end, &pinned, prev)
                    .unwrap();
                let tag = format!(
                    "seed {seed} {} window [{start},{end}) prev={}",
                    g.name,
                    prev.is_some()
                );
                assert_eq!(a.placements, b.placements, "{tag}");
                assert_cost_bits(&a.cost, &b.cost, &tag);

                let memo = MemoDevice(&d);
                let m = lat
                    .solve_range(&g, &memo, &snap, start, end, &pinned, prev)
                    .unwrap();
                assert_eq!(a.placements, m.placements, "{tag}: memo");
                assert_cost_bits(&a.cost, &m.cost, &tag);
            }
        }
    }
}

/// A warm scratch carried across *different* graphs, windows and models
/// (the controller's usage pattern) never perturbs results relative to the
/// map oracle solved cold.
#[test]
fn warm_scratch_across_graphs_matches_cold_map_oracle() {
    use adaoper::partition::dp::DpScratch;
    let mut rng = Prng::new(0x5C4A_7C8);
    let mut scratch = DpScratch::new();
    for round in 0..8 {
        let g = random_graph(&mut rng);
        let d = frozen(WorkloadCondition::high(), rng.next_u64());
        let snap = d.snapshot();
        let lat = random_solver(&mut rng);
        let map = lat.clone().with_backend(DpBackend::Map);
        let a = lat.solve_in(&g, &d, &snap, &mut scratch).unwrap();
        let b = map.solve(&g, &d, &snap).unwrap();
        assert_eq!(a.placements, b.placements, "round {round} {}", g.name);
        assert_cost_bits(&a.predicted, &b.predicted, &format!("round {round}"));
    }
}
