//! Differential property suite for the calendar event queue.
//!
//! The calendar [`EventQueue`] replaced the binary-heap queue as the
//! kernel's scheduler (PR 7). The heap survives as
//! [`BinaryHeapQueue`] — trivially correct by construction of
//! `std::collections::BinaryHeap` — and this suite drives both
//! implementations in lockstep through adversarial random streams:
//! clustered near-future times (the serving regime the calendar is
//! optimized for), duplicate timestamps (push-order tie-breaks),
//! interleaved push/pop (cursor rewinds), far-future monitor ticks
//! (overflow + re-anchor migration), and `total_cmp` edge cases (NaN,
//! ±∞, negative/past times). Every pop and peek must agree bitwise on
//! `(time, event)`; any divergence is an ordering bug in the calendar.

use adaoper::coordinator::request::Request;
use adaoper::sim::{BinaryHeapQueue, Event, EventKind, EventQueue};
use adaoper::util::Prng;

fn arrival(id: usize, t: f64) -> Event {
    Event::Arrival {
        req: Request {
            id,
            stream: id % 3,
            arrival_s: t,
            deadline_s: t + 0.25,
        },
        admitted: false,
    }
}

fn tick(t: f64) -> Event {
    Event::MonitorTick {
        t_s: t,
        regime_changed: false,
    }
}

/// Identity of a popped/peeked entry: exact time bits, event kind, and
/// the request id for arrivals (unique per push, so it witnesses the
/// seq tie-break order exactly).
fn fp(t: f64, ev: &Event) -> (u64, EventKind, Option<usize>) {
    let id = match ev {
        Event::Arrival { req, .. } => Some(req.id),
        _ => None,
    };
    (t.to_bits(), ev.kind(), id)
}

/// The two implementations under lockstep.
#[derive(Default)]
struct Pair {
    cal: EventQueue,
    heap: BinaryHeapQueue,
}

impl Pair {
    fn push(&mut self, t: f64, id: usize, is_tick: bool) {
        let ev = if is_tick { tick(t) } else { arrival(id, t) };
        self.cal.push(t, ev.clone());
        self.heap.push(t, ev);
    }

    #[track_caller]
    fn pop_agrees(&mut self) -> bool {
        assert_eq!(self.cal.len(), self.heap.len(), "length diverged");
        let a = self.cal.pop().map(|(t, ev)| fp(t, &ev));
        let b = self.heap.pop().map(|(t, ev)| fp(t, &ev));
        assert_eq!(a, b, "pop diverged");
        a.is_some()
    }

    #[track_caller]
    fn peek_agrees(&mut self) {
        assert_eq!(
            self.cal.peek_time().map(f64::to_bits),
            self.heap.peek_time().map(f64::to_bits),
            "peek_time diverged"
        );
        assert_eq!(
            self.cal.peek_arrival_time().map(f64::to_bits),
            self.heap.peek_arrival_time().map(f64::to_bits),
            "peek_arrival_time diverged"
        );
    }

    #[track_caller]
    fn drain(&mut self) {
        while self.pop_agrees() {}
        assert!(self.cal.is_empty() && self.heap.is_empty());
    }
}

/// One adversarial random workload: near-future clusters around an
/// advancing base time, duplicate timestamps, far-future ticks,
/// occasional NaN/±∞/past-time pushes, and interleaved pops.
fn run_random_workload(seed: u64, ops: usize) {
    let mut rng = Prng::new(seed);
    let mut pair = Pair::default();
    let mut next_id = 0usize;
    let mut base = 0.0f64;
    let mut last_dup = 0.5f64;
    for _ in 0..ops {
        if rng.chance(0.6) {
            // push: mostly clustered near-future, with adversarial tails
            let roll = rng.f64();
            let (t, is_tick) = if roll < 0.55 {
                (base + rng.range(0.0, 0.05), false) // near-future cluster
            } else if roll < 0.70 {
                last_dup = if rng.chance(0.3) {
                    base + rng.range(0.0, 0.02)
                } else {
                    last_dup
                };
                (last_dup, false) // duplicate timestamp → seq tie-break
            } else if roll < 0.80 {
                (base + rng.range(1.0, 500.0), true) // far-future tick
            } else if roll < 0.88 {
                (base - rng.range(0.0, 2.0), false) // past/negative time
            } else if roll < 0.92 {
                (f64::NAN, false)
            } else if roll < 0.96 {
                (f64::INFINITY, true)
            } else {
                (f64::NEG_INFINITY, false)
            };
            pair.push(t, next_id, is_tick);
            next_id += 1;
        } else if rng.chance(0.5) {
            pair.pop_agrees();
        } else {
            pair.peek_agrees();
        }
        if rng.chance(0.05) {
            base += rng.range(0.0, 0.5); // the serving clock moves on
        }
    }
    pair.drain();
}

#[test]
fn random_workloads_agree_across_seeds() {
    for seed in [1u64, 7, 42, 1234, 0xDEAD_BEEF] {
        run_random_workload(seed, 4000);
    }
}

#[test]
fn pure_near_future_cluster_agrees() {
    // the calendar's fast path: everything lands inside the bucket window
    let mut rng = Prng::new(99);
    let mut pair = Pair::default();
    for id in 0..2000 {
        pair.push(rng.range(0.0, 0.06), id, false);
    }
    pair.drain();
}

#[test]
fn duplicate_timestamp_storm_keeps_push_order() {
    // heavy tie-break pressure: few distinct times, many entries each
    let mut rng = Prng::new(5);
    let times: Vec<f64> = (0..8).map(|_| rng.range(0.0, 1.0)).collect();
    let mut pair = Pair::default();
    for id in 0..1200 {
        let t = times[rng.below(times.len())];
        pair.push(t, id, false);
        if rng.chance(0.25) {
            pair.pop_agrees();
        }
    }
    pair.drain();
}

#[test]
fn far_future_ticks_between_near_arrivals() {
    // the engine's actual mixed shape: dense arrivals plus sparse
    // monitor-style timeline events far past the initial window
    let mut rng = Prng::new(21);
    let mut pair = Pair::default();
    let mut id = 0;
    for burst in 0..40 {
        let base = burst as f64 * 30.0;
        pair.push(base + 1000.0, id, true); // far-future tick → overflow
        id += 1;
        for _ in 0..25 {
            pair.push(base + rng.range(0.0, 0.1), id, false);
            id += 1;
        }
        for _ in 0..20 {
            pair.pop_agrees(); // drains the burst, re-anchors toward the tick
        }
        pair.peek_agrees();
    }
    pair.drain();
}

#[test]
fn total_cmp_edge_cases_agree() {
    // NaN sorts last, -inf first, +inf after all finite — on both sides,
    // with seq breaking ties among equal non-finite times too
    let mut pair = Pair::default();
    let specials = [
        f64::NAN,
        f64::INFINITY,
        f64::NEG_INFINITY,
        0.0,
        -0.0,
        f64::NAN,
        1e300,
        -1e300,
        f64::NEG_INFINITY,
        5e-324, // smallest subnormal
    ];
    for (id, &t) in specials.iter().enumerate() {
        pair.push(t, id, false);
        pair.peek_agrees();
    }
    pair.drain();
}

#[test]
fn interleaved_push_pop_with_rewinds() {
    // pops advance the calendar cursor; pushes behind it must rewind —
    // alternate so the cursor keeps moving both ways
    let mut rng = Prng::new(77);
    let mut pair = Pair::default();
    let mut id = 0;
    for round in 0..300 {
        let hi = round as f64 * 0.01 + 0.05;
        for _ in 0..4 {
            pair.push(rng.range(0.0, hi), id, false);
            id += 1;
        }
        pair.pop_agrees();
        pair.pop_agrees();
        // a push earlier than everything popped so far
        pair.push(rng.range(-1.0, 0.0), id, false);
        id += 1;
        pair.pop_agrees();
    }
    pair.drain();
}
