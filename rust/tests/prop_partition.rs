//! Property tests for the partitioner (in-repo generators — no proptest in
//! the offline crate set): DP optimality vs the exhaustive oracle on random
//! chains, plan-evaluator consistency, and incremental-repair invariants.

use adaoper::experiments::ablations::random_chain;
use adaoper::graph::zoo;
use adaoper::partition::baselines::RandomPartitioner;
use adaoper::partition::dp::{DpBackend, DpPartitioner};
use adaoper::partition::exhaustive::ExhaustivePartitioner;
use adaoper::partition::incremental::IncrementalRepartitioner;
use adaoper::partition::plan::{evaluate, Objective, Partitioner};
use adaoper::soc::device::{Device, DeviceConfig};
use adaoper::soc::Placement;
use adaoper::util::Prng;
use adaoper::workload::WorkloadCondition;

fn frozen(cond: WorkloadCondition, seed: u64) -> Device {
    let mut d = Device::new(DeviceConfig {
        noise_sigma: 0.0,
        drift_sigma: 0.0,
        seed,
        ..DeviceConfig::snapdragon_855()
    });
    let mut c = cond.spec;
    c.cpu_bg_sigma = 0.0;
    c.cpu_burst = 0.0;
    c.gpu_bg_sigma = 0.0;
    c.gpu_burst = 0.0;
    c.drift_sigma = 0.0;
    d.apply_condition(&c);
    d
}

/// Property: on random chains the DP matches the exhaustive optimum for
/// every objective, under both paper conditions.
#[test]
fn dp_is_optimal_on_random_chains() {
    let choices = vec![
        Placement::CPU,
        Placement::GPU,
        Placement::Split { cpu_frac: 0.15 },
    ];
    let mut rng = Prng::new(0xFACE);
    for trial in 0..12 {
        let n = 4 + rng.below(5); // 4..8 ops → ≤ 3^8 combos
        let g = random_chain(n, rng.next_u64());
        let cond = if rng.chance(0.5) {
            WorkloadCondition::moderate()
        } else {
            WorkloadCondition::high()
        };
        let d = frozen(cond, rng.next_u64());
        let snap = d.snapshot();
        for obj in [
            Objective::MinEdp,
            Objective::MinLatency,
            Objective::MinEnergyUnderSlo { slo_s: 0.05 },
        ] {
            let dp = DpPartitioner::new(obj)
                .with_choices(choices.clone())
                .partition(&g, &d, &snap)
                .unwrap();
            let ex = ExhaustivePartitioner::new(obj, choices.clone())
                .partition(&g, &d, &snap)
                .unwrap();
            let dp_c = evaluate(&g, &dp.placements, &d, &snap);
            let ex_c = evaluate(&g, &ex.placements, &d, &snap);
            let dp_s = obj.score(dp_c.energy_j, dp_c.latency_s);
            let ex_s = obj.score(ex_c.energy_j, ex_c.latency_s);
            assert!(
                dp_s <= ex_s * 1.0001,
                "trial {trial} n={n} {obj:?}: dp {dp_s} > exhaustive {ex_s}"
            );
        }
    }
}

/// Property: DP-vs-exhaustive optimality holds for *each* objective family
/// individually — MinEdp, MinEnergyUnderSlo (a sweep of tight, achievable
/// and slack SLOs, including infeasible ones where the scoring penalty
/// decides), and MinLatency — with the Pareto lattice at a resolution high
/// enough that latency-bucket thinning never discards a point, and with a
/// denser split-choice grid than the base property uses.
#[test]
fn dp_matches_exhaustive_for_every_objective_and_slo() {
    let choices = vec![
        Placement::CPU,
        Placement::GPU,
        Placement::Split { cpu_frac: 0.15 },
        Placement::Split { cpu_frac: 0.3 },
    ];
    let mut rng = Prng::new(0xD1CE);
    for trial in 0..5 {
        let n = 4 + rng.below(3); // 4..6 ops → ≤ 4^6 = 4096 combos
        let g = random_chain(n, rng.next_u64());
        let cond = if trial % 2 == 0 {
            WorkloadCondition::moderate()
        } else {
            WorkloadCondition::high()
        };
        let d = frozen(cond, rng.next_u64());
        let snap = d.snapshot();
        let objectives = [
            Objective::MinEdp,
            Objective::MinLatency,
            Objective::MinEnergyUnderSlo { slo_s: 0.005 }, // likely infeasible
            Objective::MinEnergyUnderSlo { slo_s: 0.05 },
            Objective::MinEnergyUnderSlo { slo_s: 0.5 },   // slack
        ];
        for obj in objectives {
            // both DP backends must hit the exhaustive optimum — and agree
            // with each other bit for bit
            let solver = DpPartitioner::new(obj)
                .with_choices(choices.clone())
                .with_buckets(4096); // no thinning → DP is exact on chains
            let dp = solver.partition(&g, &d, &snap).unwrap();
            let map = solver
                .clone()
                .with_backend(DpBackend::Map)
                .partition(&g, &d, &snap)
                .unwrap();
            assert_eq!(
                dp.placements, map.placements,
                "trial {trial} n={n} {obj:?}: lattice and map backends diverge"
            );
            assert_eq!(
                dp.predicted.energy_j.to_bits(),
                map.predicted.energy_j.to_bits()
            );
            assert_eq!(
                dp.predicted.latency_s.to_bits(),
                map.predicted.latency_s.to_bits()
            );
            let ex = ExhaustivePartitioner::new(obj, choices.clone())
                .partition(&g, &d, &snap)
                .unwrap();
            let dp_c = evaluate(&g, &dp.placements, &d, &snap);
            let ex_c = evaluate(&g, &ex.placements, &d, &snap);
            let dp_s = obj.score(dp_c.energy_j, dp_c.latency_s);
            let ex_s = obj.score(ex_c.energy_j, ex_c.latency_s);
            assert!(
                dp_s <= ex_s * 1.0001,
                "trial {trial} n={n} {obj:?}: dp {dp_s} > exhaustive {ex_s}"
            );
        }
    }
}

/// Property: the DP never scores worse than random plans (50 random plans
/// per graph across the zoo).
#[test]
fn dp_beats_random_plans() {
    let mut rng = Prng::new(7);
    for name in zoo::names() {
        let g = zoo::by_name(name).unwrap();
        let d = frozen(WorkloadCondition::moderate(), 1);
        let snap = d.snapshot();
        let obj = Objective::MinEdp;
        let dp = DpPartitioner::new(obj).partition(&g, &d, &snap).unwrap();
        let dp_c = evaluate(&g, &dp.placements, &d, &snap);
        let dp_s = obj.score(dp_c.energy_j, dp_c.latency_s);
        for _ in 0..50 {
            let r = RandomPartitioner::new(rng.next_u64())
                .partition(&g, &d, &snap)
                .unwrap();
            let rc = evaluate(&g, &r.placements, &d, &snap);
            let rs = obj.score(rc.energy_j, rc.latency_s);
            assert!(
                dp_s <= rs * 1.0001,
                "{name}: dp {dp_s} beaten by random {rs}"
            );
        }
    }
}

/// Property: DP's internal prediction always equals the shared evaluator
/// (they must walk identical contexts) on random chains and zoo DAGs.
#[test]
fn dp_prediction_consistent_with_evaluator() {
    let mut rng = Prng::new(0xBEEF);
    let mut graphs: Vec<adaoper::graph::ModelGraph> = (0..6)
        .map(|_| random_chain(3 + rng.below(8), rng.next_u64()))
        .collect();
    graphs.push(zoo::yolov2());
    graphs.push(zoo::resnet18());
    for g in &graphs {
        let d = frozen(WorkloadCondition::high(), 3);
        let snap = d.snapshot();
        let plan = DpPartitioner::new(Objective::MinEdp)
            .partition(g, &d, &snap)
            .unwrap();
        let ev = evaluate(g, &plan.placements, &d, &snap);
        assert!(
            (plan.predicted.energy_j / ev.energy_j - 1.0).abs() < 1e-9,
            "{}: energy {} vs {}",
            g.name,
            plan.predicted.energy_j,
            ev.energy_j
        );
        assert!((plan.predicted.latency_s / ev.latency_s - 1.0).abs() < 1e-9);
    }
}

/// Property: incremental repair at any frontier never changes placements
/// outside its window and never degrades the plan (as DP-scored).
#[test]
fn incremental_repair_is_local_and_monotone() {
    let g = zoo::yolov2();
    let d_high = frozen(WorkloadCondition::high(), 5);
    let snap = d_high.snapshot();
    let dp = DpPartitioner::new(Objective::MinEdp);
    // stale plan from moderate
    let d_mod = frozen(WorkloadCondition::moderate(), 5);
    let stale = dp.solve(&g, &d_mod, &d_mod.snapshot()).unwrap();
    let mut rng = Prng::new(21);
    for _ in 0..10 {
        let frontier = rng.below(g.num_ops());
        let w = 1 + rng.below(12);
        let inc = IncrementalRepartitioner::new(dp.clone(), w);
        let before = inc
            .remaining_cost(&g, &stale, frontier, &d_high, &snap, None)
            .unwrap();
        let patched = inc
            .repartition(&g, &stale, frontier, &d_high, &snap, None)
            .unwrap();
        for i in 0..g.num_ops() {
            if !(frontier..frontier + w).contains(&i) {
                assert_eq!(
                    patched.placements[i], stale.placements[i],
                    "op {i} changed outside window [{frontier},{})",
                    frontier + w
                );
            }
        }
        let after = inc
            .remaining_cost(&g, &patched, frontier, &d_high, &snap, None)
            .unwrap();
        assert!(
            after.energy_j * after.latency_s
                <= before.energy_j * before.latency_s * 1.0001,
            "repair degraded plan at frontier {frontier} w {w}"
        );
    }
}

/// Property: transfer seconds appear exactly when placement boundaries
/// cross processors.
#[test]
fn transfer_costs_iff_boundaries() {
    let g = zoo::yolov2_tiny();
    let d = frozen(WorkloadCondition::moderate(), 9);
    let snap = d.snapshot();
    let gpu_cost = evaluate(&g, &vec![Placement::GPU; g.num_ops()], &d, &snap);
    let cpu_cost = evaluate(&g, &vec![Placement::CPU; g.num_ops()], &d, &snap);
    // all-GPU pays exactly one input upload (camera buffer is CPU-side),
    // all-CPU pays none
    assert!(gpu_cost.transfer_s > 0.0);
    assert_eq!(cpu_cost.transfer_s, 0.0);
    // alternating placements pay strictly more transfer
    let alt: Vec<Placement> = (0..g.num_ops())
        .map(|i| if i % 2 == 0 { Placement::CPU } else { Placement::GPU })
        .collect();
    let alt_cost = evaluate(&g, &alt, &d, &snap);
    assert!(alt_cost.transfer_s > gpu_cost.transfer_s);
}
