//! Property tests on the SoC simulator: cost-model invariants that must
//! hold for any operator, placement and device state (seeded random
//! sweeps — the simulator is the experiments' ground truth, so its
//! monotonicities must be unconditional).

use adaoper::graph::zoo;
use adaoper::soc::device::{ConditionSpec, Device, DeviceConfig, ExecCtx};
use adaoper::soc::{Placement, Proc};
use adaoper::util::Prng;

fn random_spec(rng: &mut Prng) -> ConditionSpec {
    ConditionSpec {
        name: "prop",
        cpu_freq_hz: Some(rng.range(0.7e9, 2.4e9)),
        gpu_freq_hz: Some(rng.range(257e6, 675e6)),
        cpu_bg_mean: rng.range(0.0, 0.7),
        cpu_bg_sigma: 0.0,
        cpu_burst: 0.0,
        gpu_bg_mean: rng.range(0.0, 0.3),
        gpu_bg_sigma: 0.0,
        gpu_burst: 0.0,
        bw_ambient: rng.range(0.75, 1.0),
        drift_sigma: 0.0,
    }
}

fn frozen(spec: &ConditionSpec, seed: u64) -> Device {
    let mut d = Device::new(DeviceConfig {
        noise_sigma: 0.0,
        drift_sigma: 0.0,
        seed,
        ..DeviceConfig::snapdragon_855()
    });
    d.apply_condition(spec);
    d
}

fn all_ops() -> Vec<adaoper::graph::OpNode> {
    let mut out = Vec::new();
    for name in zoo::names() {
        out.extend(zoo::by_name(name).unwrap().ops);
    }
    out
}

/// Costs are strictly positive and finite for every op × placement × state.
#[test]
fn costs_positive_and_finite_everywhere() {
    let ops = all_ops();
    let mut rng = Prng::new(1);
    for trial in 0..30 {
        let spec = random_spec(&mut rng);
        let d = frozen(&spec, trial);
        let op = &ops[rng.below(ops.len())];
        for placement in [
            Placement::CPU,
            Placement::GPU,
            Placement::Split { cpu_frac: rng.range(0.05, 0.9) },
        ] {
            let ctx = ExecCtx::fresh(vec![
                placement.frac_on(Proc::Cpu);
                op.in_shapes.len()
            ]);
            let c = d.expected_cost(op, placement, &ctx);
            assert!(c.latency_s.is_finite() && c.latency_s > 0.0, "{op:?} {placement}");
            assert!(c.energy_j.is_finite() && c.energy_j > 0.0, "{op:?} {placement}");
            assert!(c.latency_s < 30.0, "absurd latency {}", c.latency_s);
        }
    }
}

/// Monotonicity: more background CPU load never makes a CPU op faster.
#[test]
fn cpu_load_monotone_latency() {
    let ops = all_ops();
    let mut rng = Prng::new(2);
    for trial in 0..25 {
        let mut spec = random_spec(&mut rng);
        let op = &ops[rng.below(ops.len())];
        let ctx = ExecCtx::fresh(vec![1.0; op.in_shapes.len()]);
        spec.cpu_bg_mean = 0.1;
        let lo = frozen(&spec, trial).expected_cost(op, Placement::CPU, &ctx);
        spec.cpu_bg_mean = 0.6;
        let hi = frozen(&spec, trial).expected_cost(op, Placement::CPU, &ctx);
        assert!(
            hi.latency_s >= lo.latency_s * 0.999,
            "trial {trial}: load sped up {} ({} → {})",
            op.name,
            lo.latency_s,
            hi.latency_s
        );
    }
}

/// Monotonicity: lower frequency never reduces compute-bound latency.
#[test]
fn frequency_monotone_latency() {
    let g = zoo::yolov2();
    let mut rng = Prng::new(3);
    for trial in 0..25 {
        let mut spec = random_spec(&mut rng);
        let op = &g.ops[2]; // heavy conv (compute-bound everywhere)
        let ctx = ExecCtx::fresh(vec![0.0; op.in_shapes.len()]);
        spec.gpu_freq_hz = Some(675e6);
        let fast = frozen(&spec, trial).expected_cost(op, Placement::GPU, &ctx);
        spec.gpu_freq_hz = Some(257e6);
        let slow = frozen(&spec, trial).expected_cost(op, Placement::GPU, &ctx);
        assert!(slow.latency_s > fast.latency_s, "trial {trial}");
    }
}

/// Split latency is bounded below by the slower-unit share and above by
/// running the whole op on either unit alone (plus overheads).
#[test]
fn split_latency_sandwiched() {
    let ops = all_ops();
    let mut rng = Prng::new(4);
    for trial in 0..25 {
        let spec = random_spec(&mut rng);
        let d = frozen(&spec, trial);
        let op = &ops[rng.below(ops.len())];
        if op.flops < 1_000_000 {
            continue; // dispatch-dominated ops aren't informative
        }
        let r = rng.range(0.1, 0.5);
        let ctx_split = ExecCtx::fresh(vec![r; op.in_shapes.len()]);
        let split = d.expected_cost(op, Placement::Split { cpu_frac: r }, &ctx_split);
        let ctx_cpu = ExecCtx::fresh(vec![1.0; op.in_shapes.len()]);
        let cpu = d.expected_cost(op, Placement::CPU, &ctx_cpu);
        // the CPU executes r of the work: the split can't be slower than
        // CPU alone doing everything (same state, generous 1.05 slack for
        // contention)
        assert!(
            split.latency_s <= cpu.latency_s * 1.05 + 1e-3,
            "trial {trial} {}: split {} vs cpu {}",
            op.name,
            split.latency_s,
            cpu.latency_s
        );
        // and busy times must cover the latency (minus transfer/sync)
        assert!(split.cpu_busy_s.max(split.gpu_busy_s) <= split.latency_s + 1e-12);
    }
}

/// Energy conservation: op energy ≥ transfer energy component, and
/// measured noise stays within ±5σ of the expectation.
#[test]
fn energy_components_consistent() {
    let ops = all_ops();
    let mut rng = Prng::new(5);
    for trial in 0..25 {
        let spec = random_spec(&mut rng);
        let mut d = frozen(&spec, trial);
        let op = &ops[rng.below(ops.len())];
        let ctx = ExecCtx::fresh(vec![0.0; op.in_shapes.len()]);
        let e = d.expected_cost(op, Placement::GPU, &ctx);
        assert!(e.energy_j >= e.transfer_j);
        assert!(e.latency_s >= e.transfer_s);
        let m = d.measure(op, Placement::GPU, &ctx);
        let ratio = (m.energy_j / e.energy_j).ln().abs();
        assert!(ratio < 5.0 * 0.04 + 0.01, "noise ratio {ratio}");
    }
}

/// The governor + thermal loop keeps state in bounds over long traces.
#[test]
fn long_advance_keeps_state_bounded() {
    let mut d = Device::new(DeviceConfig::snapdragon_855());
    d.apply_condition(&adaoper::workload::WorkloadCondition::high().spec);
    let mut rng = Prng::new(6);
    for _ in 0..20_000 {
        d.advance(0.01, rng.f64(), rng.f64());
        let s = d.snapshot();
        assert!((0.0..=1.0).contains(&s.cpu_util));
        assert!((0.0..=1.0).contains(&s.gpu_util));
        assert!(s.temp_c > 10.0 && s.temp_c < 120.0, "temp {}", s.temp_c);
        assert!(s.cpu_freq_hz > 0.0 && s.gpu_freq_hz > 0.0);
    }
}
