//! The four ablation scenarios ported onto declarative specs must
//! reproduce the hand-written configurations exactly: running the spec
//! under `scenarios/` yields a report row byte-identical to the row from
//! an `EngineConfig` (or `FleetRunConfig`) constructed in code, and the
//! spec's `[expect]` bounds hold.

use std::path::{Path, PathBuf};

use adaoper::config::schema::{ConditionKind, PolicyKind, SchedulerKind};
use adaoper::coordinator::{AdmissionPolicy, Engine, EngineConfig, StreamSpec};
use adaoper::fleet::{run_fleet, FleetRunConfig};
use adaoper::graph::zoo;
use adaoper::scenario;
use adaoper::workload::Arrival;

fn spec_src(file: &str) -> String {
    let path: PathBuf =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("scenarios").join(file);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

fn base_cfg(duration_s: f64, seed: u64, samples: usize, trees: usize) -> EngineConfig {
    let mut cfg = EngineConfig {
        policy: PolicyKind::AdaOper,
        condition: ConditionKind::Moderate,
        duration_s,
        seed,
        ..EngineConfig::default()
    };
    cfg.calib.samples = samples;
    cfg.calib.seed = 42;
    cfg.calib.gbdt.trees = trees;
    cfg
}

fn stream(id: usize, model: &str, arrival: &str, hz: f64, slo_ms: f64) -> StreamSpec {
    StreamSpec::new(
        id,
        zoo::by_name(model).unwrap(),
        Arrival::parse(arrival, hz, 0.0).unwrap(),
        slo_ms / 1e3,
    )
}

#[test]
fn cache_port_matches_hand_written_row() {
    let outcome = scenario::run_str(&spec_src("cache_recurrence.toml")).unwrap();

    let mut cfg = base_cfg(2.0, 7, 1200, 40);
    cfg.plan_cache.capacity = 32;
    cfg.plan_cache.util_bucket = 0.5;
    cfg.plan_cache.freq_bucket_hz = 50.0 * 1e6;
    cfg.condition_timeline = vec![
        (0.5, ConditionKind::High),
        (1.0, ConditionKind::Moderate),
        (1.5, ConditionKind::High),
    ];
    let streams = vec![
        stream(0, "yolov2-tiny", "poisson", 10.0, 500.0),
        stream(1, "mobilenetv1", "poisson", 10.0, 500.0),
    ];
    let report = Engine::new(cfg).run(&streams).unwrap();

    assert_eq!(outcome.row, report.row(), "spec-lowered row diverged from hand-written config");
    assert!(outcome.passed(), "expect bounds failed: {:?}", outcome.checks);
}

#[test]
fn scheduler_port_matches_hand_written_row() {
    let outcome = scenario::run_str(&spec_src("scheduler_overload.toml")).unwrap();

    let mut cfg = base_cfg(1.2, 11, 1200, 40);
    cfg.scheduler = SchedulerKind::Edf;
    cfg.admission = AdmissionPolicy::DropLate;
    let streams = vec![stream(0, "yolov2-tiny", "poisson", 40.0, 120.0)];
    let report = Engine::new(cfg).run(&streams).unwrap();

    assert_eq!(outcome.row, report.row(), "spec-lowered row diverged from hand-written config");
    assert!(outcome.passed(), "expect bounds failed: {:?}", outcome.checks);
}

#[test]
fn batching_port_matches_hand_written_row() {
    let outcome = scenario::run_str(&spec_src("batching_burst.toml")).unwrap();

    let mut cfg = base_cfg(1.5, 13, 1200, 40);
    cfg.scheduler = SchedulerKind::Edf;
    cfg.batching.policy = adaoper::config::schema::BatchPolicyKind::Slack;
    cfg.batching.max = 4;
    cfg.batching.wait_s = 4.0 / 1e3;
    let streams = vec![stream(0, "yolov2-tiny", "mmpp", 30.0, 300.0)];
    let report = Engine::new(cfg).run(&streams).unwrap();

    assert_eq!(outcome.row, report.row(), "spec-lowered row diverged from hand-written config");
    assert!(outcome.passed(), "expect bounds failed: {:?}", outcome.checks);
}

#[test]
fn fleet_port_matches_hand_written_render() {
    let outcome = scenario::run_str(&spec_src("fleet_scale.toml")).unwrap();

    let mut fcfg = FleetRunConfig {
        devices: 6,
        threads: 4,
        seed: 7,
        duration_s: 1.0,
        policy: PolicyKind::AdaOper,
        scheduler: SchedulerKind::Edf,
        admission: AdmissionPolicy::AdmitAll,
        ..FleetRunConfig::default()
    };
    fcfg.calib.samples = 900;
    fcfg.calib.seed = 42;
    fcfg.calib.gbdt.trees = 30;
    let report = run_fleet(&fcfg).unwrap();

    assert_eq!(outcome.row, report.render(), "spec-lowered fleet render diverged");
    assert!(outcome.passed(), "expect bounds failed: {:?}", outcome.checks);
}
