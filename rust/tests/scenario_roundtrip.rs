//! Round-trip property test: for random valid scenario specs, emitting
//! the spec back to TOML and re-parsing must lower to an identical
//! `EngineConfig` (witnessed by the trace-header fingerprint — floats are
//! printed shortest-round-trip, so equality is exact) and, when run,
//! produce a byte-identical report row.

use adaoper::config::schema::{
    AdmissionKind, BatchPolicyKind, ConditionKind, PolicyKind, SchedulerKind,
};
use adaoper::coordinator::Engine;
use adaoper::metrics::HealthConfig;
use adaoper::scenario::spec::{
    BatchDef, CacheDef, CalibDef, ObjectiveDef, ScenarioSpec, StreamDef, TimelineDef,
};
use adaoper::scenario::{fingerprint, lower, parse_spec, ExpectBound, ExpectKey};
use adaoper::util::Prng;

const MODELS: &[&str] = &["yolov2-tiny", "mobilenetv1", "tiny-exec"];
const ARRIVALS: &[&str] = &["poisson", "periodic", "mmpp"];

fn random_spec(rng: &mut Prng, tag: usize) -> ScenarioSpec {
    let duration_s = 1.0;
    let scheduler = *rng.choose(&SchedulerKind::all());
    let admission = *rng.choose(&AdmissionKind::all());
    let queue_limit =
        if admission == AdmissionKind::Bounded { Some(2 + rng.below(3)) } else { None };
    let policy = *rng.choose(&[PolicyKind::AdaOper, PolicyKind::MaceGpu, PolicyKind::AllCpu]);
    let objective = match rng.below(3) {
        0 => ObjectiveDef::MinEdp,
        1 => ObjectiveDef::MinLatency,
        _ => ObjectiveDef::MinEnergySlo { slo_ms: rng.range(150.0, 600.0) },
    };
    let batching = match rng.below(3) {
        0 => BatchDef::default(),
        1 => BatchDef { policy: BatchPolicyKind::Fixed, max: 2 + rng.below(3), wait_ms: rng.range(1.0, 6.0) },
        _ => BatchDef { policy: BatchPolicyKind::Slack, max: 2 + rng.below(3), wait_ms: rng.range(1.0, 6.0) },
    };

    let n_streams = 1 + rng.below(2);
    let mut stream_names = Vec::new();
    let mut streams = Vec::new();
    for i in 0..n_streams {
        let arrival = rng.choose(ARRIVALS).to_string();
        let jitter = if arrival == "periodic" { Some(rng.range(0.0, 0.3)) } else { None };
        let name = format!("s{i}");
        stream_names.push(name.clone());
        streams.push(StreamDef {
            name,
            model: rng.choose(MODELS).to_string(),
            arrival,
            rate_hz: rng.range(8.0, 25.0),
            jitter,
            slo_ms: rng.range(150.0, 600.0),
        });
    }

    let mut timeline = Vec::new();
    let n_boundaries = rng.below(3);
    for (i, frac) in [0.3, 0.7].iter().enumerate().take(n_boundaries) {
        timeline.push(TimelineDef {
            label: format!("t{i}"),
            // distinct by construction: 0.3 vs 0.7 of the horizon, jittered
            // within non-overlapping windows
            at_s: duration_s * (frac + rng.range(-0.1, 0.1)),
            condition: *rng.choose(&[ConditionKind::Idle, ConditionKind::High]),
        });
    }

    // half the specs carry a [health] section with randomized (valid)
    // knobs, so the round-trip covers its floats and integers too
    let health = if rng.below(2) == 0 {
        None
    } else {
        Some(HealthConfig {
            fast_window_s: rng.range(0.4, 0.9),
            slow_window_s: rng.range(2.0, 6.0),
            slo_target: rng.range(0.005, 0.2),
            energy_budget_mj: if rng.below(2) == 0 { 0.0 } else { rng.range(5.0, 50.0) },
            min_samples: 1 + rng.below(8) as u64,
            ..HealthConfig::default()
        })
    };

    ScenarioSpec {
        name: format!("roundtrip-{tag}"),
        duration_s,
        seed: rng.below(1_000_000) as u64,
        policy,
        objective,
        scheduler,
        admission,
        queue_limit,
        condition: *rng.choose(&[ConditionKind::Moderate, ConditionKind::High]),
        stream_names,
        streams,
        timeline,
        calib: CalibDef { samples: 900, seed: 42, trees: 25 },
        batching,
        plan_cache: CacheDef::default(),
        fleet: None,
        health,
        expect: vec![
            ExpectBound { key: ExpectKey::RequestsMin, bound: 0.0 },
            ExpectBound { key: ExpectKey::MissPctMax, bound: 100.0 },
        ],
    }
}

#[test]
fn emit_reparse_lower_is_identity() {
    // structural identity across many samples (no engine runs: cheap)
    let mut rng = Prng::new(0x5CE7A810);
    for tag in 0..24 {
        let spec = random_spec(&mut rng, tag);
        let emitted = spec.emit();
        let reparsed = parse_spec(&emitted)
            .unwrap_or_else(|e| panic!("emitted spec failed to re-parse: {e}\n{emitted}"));
        assert_eq!(spec, reparsed, "decode(emit(spec)) != spec\n{emitted}");

        let a = lower(&spec).unwrap();
        let b = lower(&reparsed).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b), "lowered configs diverged\n{emitted}");
    }
}

#[test]
fn reparsed_spec_runs_byte_identically() {
    // end-to-end: run both lowerings and compare report rows exactly
    let mut rng = Prng::new(0x5CE7A811);
    for tag in 0..2 {
        let spec = random_spec(&mut rng, tag);
        let reparsed = parse_spec(&spec.emit()).unwrap();

        let a = lower(&spec).unwrap();
        let b = lower(&reparsed).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b));

        let row_a = Engine::new(a.cfg.clone()).run(&a.streams).unwrap().row();
        let row_b = Engine::new(b.cfg.clone()).run(&b.streams).unwrap().row();
        assert_eq!(row_a, row_b, "re-emitted spec ran differently (tag {tag})");
    }
}
