//! Validator diagnostics suite: malformed scenario specs must be
//! rejected with errors that *name the offending section and key* —
//! never a panic, never a context-free message. Each case corrupts one
//! aspect of a known-good base spec and asserts the diagnostic points at
//! it.

use adaoper::scenario::parse_spec;

const BASE: &str = "\
[scenario]
name = \"base\"
duration_s = 2.0
seed = 7
policy = \"adaoper\"
scheduler = \"fifo\"
admission = \"admit-all\"
condition = \"moderate\"
streams = [\"cam\"]

[stream.cam]
model = \"yolov2-tiny\"
arrival = \"poisson\"
rate_hz = 30.0
slo_ms = 250.0
";

/// The base spec itself must be valid — otherwise every case below is
/// vacuous.
#[test]
fn base_spec_is_valid() {
    parse_spec(BASE).unwrap();
}

fn err_of(src: &str) -> String {
    match parse_spec(src) {
        Ok(_) => panic!("spec unexpectedly valid:\n{src}"),
        Err(e) => e.to_string(),
    }
}

/// Corrupt BASE by replacing one line, return the diagnostic.
fn err_replacing(from: &str, to: &str) -> String {
    assert!(BASE.contains(from), "base spec lacks `{from}`");
    err_of(&BASE.replace(from, to))
}

fn assert_names(err: &str, needles: &[&str]) {
    for n in needles {
        assert!(err.contains(n), "error does not name `{n}`: {err}");
    }
}

#[test]
fn missing_scenario_section() {
    let src = BASE.replace("[scenario]", "[calib]").replace("name = \"base\"", "samples = 900");
    // everything that was in [scenario] is now an unknown [calib] key, or
    // the [scenario] section is simply absent — either way the error
    // must name the offending place
    let err = err_of(&src);
    assert!(err.contains("scenario") || err.contains("calib"), "unhelpful error: {err}");
}

#[test]
fn missing_name() {
    let err = err_replacing("name = \"base\"", "");
    assert_names(&err, &["[scenario]", "name", "missing"]);
}

#[test]
fn zero_duration() {
    let err = err_replacing("duration_s = 2.0", "duration_s = 0.0");
    assert_names(&err, &["[scenario]", "duration_s", "> 0"]);
}

#[test]
fn negative_duration_carries_line_number() {
    let err = err_replacing("duration_s = 2.0", "duration_s = -1.5");
    assert_names(&err, &["[scenario]", "duration_s", "line 3"]);
}

#[test]
fn unknown_policy() {
    let err = err_replacing("policy = \"adaoper\"", "policy = \"warp-drive\"");
    assert_names(&err, &["[scenario]", "policy"]);
}

#[test]
fn unknown_scheduler() {
    let err = err_replacing("scheduler = \"fifo\"", "scheduler = \"lifo\"");
    assert_names(&err, &["[scenario]", "scheduler"]);
}

#[test]
fn unknown_admission() {
    let err = err_replacing("admission = \"admit-all\"", "admission = \"sometimes\"");
    assert_names(&err, &["[scenario]", "admission"]);
}

#[test]
fn unknown_condition() {
    let err = err_replacing("condition = \"moderate\"", "condition = \"melting\"");
    assert_names(&err, &["[scenario]", "condition"]);
}

#[test]
fn empty_stream_list() {
    let src = BASE
        .replace("streams = [\"cam\"]", "streams = []")
        .replace("[stream.cam]", "")
        .replace("model = \"yolov2-tiny\"", "")
        .replace("arrival = \"poisson\"", "")
        .replace("rate_hz = 30.0", "")
        .replace("slo_ms = 250.0", "");
    let err = err_of(&src);
    assert_names(&err, &["[scenario]", "streams"]);
}

#[test]
fn dangling_stream_ref() {
    let err = err_replacing("streams = [\"cam\"]", "streams = [\"cam\", \"ghost\"]");
    assert_names(&err, &["[scenario]", "streams", "ghost"]);
}

#[test]
fn duplicate_stream_ref() {
    let err = err_replacing("streams = [\"cam\"]", "streams = [\"cam\", \"cam\"]");
    assert_names(&err, &["[scenario]", "streams", "twice"]);
}

#[test]
fn orphan_stream_section() {
    let src = format!(
        "{BASE}\n[stream.orphan]\nmodel = \"mobilenetv1\"\narrival = \"poisson\"\n\
         rate_hz = 5.0\nslo_ms = 400.0\n"
    );
    let err = err_of(&src);
    assert_names(&err, &["[stream.orphan]", "not listed"]);
}

#[test]
fn unknown_model() {
    let err = err_replacing("model = \"yolov2-tiny\"", "model = \"gpt-17\"");
    assert_names(&err, &["[stream.cam]", "model", "gpt-17"]);
}

#[test]
fn unknown_arrival_kind() {
    let err = err_replacing("arrival = \"poisson\"", "arrival = \"quantum\"");
    assert_names(&err, &["[stream.cam]", "arrival", "quantum"]);
}

#[test]
fn non_positive_rate() {
    let err = err_replacing("rate_hz = 30.0", "rate_hz = 0.0");
    assert_names(&err, &["[stream.cam]", "rate_hz", "> 0"]);
}

#[test]
fn jitter_on_non_periodic_arrival() {
    let err = err_replacing("rate_hz = 30.0", "rate_hz = 30.0\njitter = 0.1");
    assert_names(&err, &["[stream.cam]", "jitter", "periodic"]);
}

#[test]
fn jitter_out_of_range() {
    let src = BASE
        .replace("arrival = \"poisson\"", "arrival = \"periodic\"")
        .replace("rate_hz = 30.0", "rate_hz = 30.0\njitter = 1.5");
    let err = err_of(&src);
    assert_names(&err, &["[stream.cam]", "jitter", "[0, 1]"]);
}

#[test]
fn unsatisfiable_slo() {
    let err = err_replacing("slo_ms = 250.0", "slo_ms = 0.2");
    assert_names(&err, &["[stream.cam]", "slo_ms", "unsatisfiable"]);
}

#[test]
fn timeline_entry_past_horizon() {
    let src = format!("{BASE}\n[timeline.late]\nat_s = 5.0\ncondition = \"high\"\n");
    let err = err_of(&src);
    assert_names(&err, &["[timeline.late]", "at_s"]);
}

#[test]
fn overlapping_timeline_entries() {
    let src = format!(
        "{BASE}\n[timeline.a]\nat_s = 1.0\ncondition = \"high\"\n\
         \n[timeline.b]\nat_s = 1.0\ncondition = \"idle\"\n"
    );
    let err = err_of(&src);
    assert_names(&err, &["at_s", "overlaps"]);
}

#[test]
fn unknown_key_in_scenario() {
    let err = err_replacing("seed = 7", "seed = 7\nwarp_factor = 9");
    assert_names(&err, &["[scenario]", "warp_factor", "unknown key"]);
}

#[test]
fn unknown_section() {
    let err = err_of(&format!("{BASE}\n[telemetry]\nenabled = true\n"));
    assert_names(&err, &["telemetry", "unknown section"]);
}

#[test]
fn unknown_expect_key() {
    let err = err_of(&format!("{BASE}\n[expect]\nvibes_min = 1.0\n"));
    assert_names(&err, &["[expect]", "vibes_min"]);
}

#[test]
fn negative_expect_bound() {
    let err = err_of(&format!("{BASE}\n[expect]\nmiss_pct_max = -1.0\n"));
    assert_names(&err, &["[expect]", "miss_pct_max", ">= 0"]);
}

#[test]
fn zero_batch_cap() {
    let err = err_of(&format!("{BASE}\n[batching]\npolicy = \"fixed\"\nmax = 0\n"));
    assert_names(&err, &["[batching]", "max", ">= 1"]);
}

#[test]
fn bounded_admission_without_queue_limit() {
    let err = err_replacing("admission = \"admit-all\"", "admission = \"bounded\"");
    assert_names(&err, &["[scenario]", "queue_limit", "bounded"]);
}

#[test]
fn queue_limit_without_bounded_admission() {
    let err = err_replacing("seed = 7", "seed = 7\nqueue_limit = 4");
    assert_names(&err, &["[scenario]", "queue_limit", "bounded"]);
}

#[test]
fn mistyped_value() {
    let err = err_replacing("duration_s = 2.0", "duration_s = \"fast\"");
    assert_names(&err, &["[scenario]", "duration_s", "number"]);
}

#[test]
fn objective_slo_without_slo_objective() {
    let err = err_replacing("seed = 7", "seed = 7\nobjective_slo_ms = 100.0");
    assert_names(&err, &["[scenario]", "objective_slo_ms", "min-energy-slo"]);
}

#[test]
fn fleet_with_stream_sections() {
    let err = err_of(&format!("{BASE}\n[fleet]\ndevices = 4\nthreads = 2\n"));
    assert_names(&err, &["[stream.cam]", "fleet"]);
}

#[test]
fn fleet_with_unsupported_expect_key() {
    let src = "\
[scenario]
name = \"f\"
duration_s = 1.0

[fleet]
devices = 4
threads = 2

[expect]
cache_hit_pct_min = 1.0
";
    let err = err_of(src);
    assert_names(&err, &["[expect]", "cache_hit_pct_min", "fleet"]);
}

#[test]
fn zero_fleet_devices() {
    let src = "\
[scenario]
name = \"f\"
duration_s = 1.0

[fleet]
devices = 0
threads = 2
";
    let err = err_of(src);
    assert_names(&err, &["[fleet]", "devices", ">= 1"]);
}
