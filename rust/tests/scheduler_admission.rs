//! Integration: drop-late admission must protect admitted requests.
//!
//! In the oracle-cost setting (the planner and the admission controller
//! both see ground-truth expected costs), a request that passes drop-late
//! admission was predicted — conservatively, with the serialized-backlog
//! bound plus safety margin — to finish inside its deadline. Admitted
//! requests must therefore never be reported as deadline misses, while
//! overload shows up as shed requests instead of queueing collapse.

use adaoper::config::schema::{PolicyKind, SchedulerKind};
use adaoper::coordinator::engine::PlannerInfo;
use adaoper::coordinator::{AdmissionPolicy, Engine, EngineConfig, StreamSpec};
use adaoper::graph::zoo;
use adaoper::profiler::calibrate::CalibConfig;
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::workload::Arrival;

fn quick_calib(seed: u64) -> CalibConfig {
    CalibConfig {
        samples: 1200,
        seed,
        gbdt: GbdtParams {
            trees: 40,
            ..Default::default()
        },
    }
}

fn overloaded_run(scheduler: SchedulerKind, seed: u64) -> adaoper::metrics::ServingReport {
    let mut e = Engine::new(EngineConfig {
        duration_s: 2.5,
        seed,
        policy: PolicyKind::MaceGpu,
        planner_info: PlannerInfo::Oracle,
        scheduler,
        admission: AdmissionPolicy::DropLate,
        calib: quick_calib(seed),
        ..Default::default()
    });
    // a single stream far past saturation with a moderate SLO:
    // drop-late must shed the infeasible tail and keep the rest on time
    let streams = vec![StreamSpec::new(
        0,
        zoo::yolov2_tiny(),
        Arrival::Poisson { hz: 300.0 },
        0.35,
    )];
    e.run(&streams).unwrap()
}

#[test]
fn drop_late_admitted_requests_never_miss_oracle_fifo() {
    let r = overloaded_run(SchedulerKind::Fifo, 11);
    let sc = r.sched.clone().unwrap();
    assert!(sc.shed_late > 0, "overload produced no shedding: {sc:?}");
    assert!(r.requests > 0, "everything was shed");
    assert_eq!(sc.offered, sc.admitted + sc.shed_late);
    assert_eq!(
        sc.deadline_misses, 0,
        "admitted requests missed deadlines: {sc:?} (miss rate {:.4})",
        r.miss_rate
    );
    assert_eq!(r.miss_rate, 0.0);
}

#[test]
fn drop_late_admitted_requests_never_miss_oracle_edf() {
    let r = overloaded_run(SchedulerKind::Edf, 13);
    let sc = r.sched.clone().unwrap();
    assert!(sc.shed_late > 0, "overload produced no shedding: {sc:?}");
    assert!(r.requests > 0, "everything was shed");
    assert_eq!(sc.deadline_misses, 0, "{sc:?}");
}

#[test]
fn admit_all_baseline_misses_under_same_overload() {
    // the same overload without admission control must actually produce
    // misses — otherwise the drop-late assertions above prove nothing
    let mut e = Engine::new(EngineConfig {
        duration_s: 2.5,
        seed: 11,
        policy: PolicyKind::MaceGpu,
        planner_info: PlannerInfo::Oracle,
        scheduler: SchedulerKind::Fifo,
        admission: AdmissionPolicy::AdmitAll,
        calib: quick_calib(11),
        ..Default::default()
    });
    let streams = vec![StreamSpec::new(
        0,
        zoo::yolov2_tiny(),
        Arrival::Poisson { hz: 300.0 },
        0.35,
    )];
    let r = e.run(&streams).unwrap();
    assert!(
        r.miss_rate > 0.2,
        "overload too mild for the control arm: miss {:.3}",
        r.miss_rate
    );
}
