//! Telemetry-layer integration: the fleet telemetry registry must be
//! bit-identical across thread counts (same contract `tests/fleet.rs`
//! pins for `FleetReport`), the plan-decision audit summary must match a
//! hand-computed oracle over the raw per-decision accumulators on a
//! fixed-seed run with forced regime drift, enabling telemetry must
//! only *append* to the report row — the telemetry-off row is a
//! byte-exact prefix of the telemetry-on row — and the two DP solver
//! backends must produce byte-identical audited runs while the lattice
//! backend's measured solve wall-clock does not regress past the map
//! reference.

use std::sync::OnceLock;

use adaoper::config::schema::{ConditionKind, PolicyKind, SchedulerKind};
use adaoper::coordinator::{AdmissionPolicy, Engine, EngineConfig, StreamSpec};
use adaoper::partition::dp::DpBackend;
use adaoper::fleet::runner::{calibrate_classes, run_fleet_with};
use adaoper::fleet::{DeviceClass, FleetReport, FleetRunConfig};
use adaoper::graph::zoo;
use adaoper::metrics::ServingReport;
use adaoper::profiler::calibrate::{calibrate_on, CalibConfig, OfflineModel};
use adaoper::profiler::gbdt::GbdtParams;
use adaoper::profiler::{EnergyProfiler, EwmaCorrector};
use adaoper::soc::device::DeviceConfig;
use adaoper::soc::Proc;
use adaoper::workload::Arrival;

const SEED: u64 = 17;

fn calib() -> CalibConfig {
    CalibConfig {
        samples: 1200,
        seed: 5,
        gbdt: GbdtParams {
            trees: 40,
            ..Default::default()
        },
    }
}

/// One shared offline model (the GBDT fit is deterministic but
/// expensive).
fn offline() -> &'static OfflineModel {
    static OFF: OnceLock<OfflineModel> = OnceLock::new();
    OFF.get_or_init(|| calibrate_on(&calib(), &DeviceConfig::snapdragon_855()))
}

fn streams() -> Vec<StreamSpec> {
    vec![
        StreamSpec::new(0, zoo::yolov2_tiny(), Arrival::Poisson { hz: 30.0 }, 0.25),
        StreamSpec::new(1, zoo::mobilenet_v1(), Arrival::Poisson { hz: 20.0 }, 0.4),
    ]
}

/// Fixed-seed AdaOper run with a mid-run regime change, so the audit log
/// is guaranteed at least one recorded plan decision.
fn drift_config(telemetry: bool) -> EngineConfig {
    EngineConfig {
        policy: PolicyKind::AdaOper,
        scheduler: SchedulerKind::Edf,
        admission: AdmissionPolicy::DropLate,
        duration_s: 1.2,
        seed: SEED,
        calib: calib(),
        condition_timeline: vec![(0.5, ConditionKind::High)],
        telemetry,
        ..Default::default()
    }
}

fn run_drift(telemetry: bool) -> (ServingReport, Engine) {
    let profiler = EnergyProfiler::with_correctors(offline().clone(), || {
        Box::new(EwmaCorrector::default())
    });
    let mut engine = Engine::with_profiler(drift_config(telemetry), profiler);
    let report = engine.run(&streams()).unwrap();
    (report, engine)
}

#[test]
fn audit_summary_matches_hand_computed_oracle() {
    let (report, engine) = run_drift(true);
    let audit = engine.audit().expect("telemetry on ⇒ audit log present");
    let decisions = audit.decisions();
    assert!(!decisions.is_empty(), "regime change at 0.5 s recorded no plan decision");

    // oracle: recompute the summary straight from the raw accumulators
    let mut residuals_ms: Vec<f64> = Vec::new();
    for d in decisions {
        for p in [Proc::Cpu, Proc::Gpu] {
            let i = p.index();
            if d.ops[i] > 0 {
                residuals_ms.push((d.actual_s[i] - d.pred_s[i]) * 1e3);
                // residual_s must agree with the raw fields it derives from
                let r = d.residual_s(p).unwrap();
                assert_eq!(r.to_bits(), (d.actual_s[i] - d.pred_s[i]).to_bits());
            } else {
                assert_eq!(d.residual_s(p), None);
            }
        }
    }
    residuals_ms.sort_by(f64::total_cmp);
    let median = if residuals_ms.is_empty() {
        None
    } else {
        let n = residuals_ms.len();
        Some(if n % 2 == 1 {
            residuals_ms[n / 2]
        } else {
            0.5 * (residuals_ms[n / 2 - 1] + residuals_ms[n / 2])
        })
    };

    let summary = audit.summary();
    assert_eq!(summary.decisions, decisions.len());
    assert_eq!(summary.drift, decisions.iter().filter(|d| d.trigger == "drift").count());
    assert_eq!(
        summary.regime,
        decisions.iter().filter(|d| d.trigger == "regime-change").count()
    );
    assert_eq!(summary.drift + summary.regime, summary.decisions);
    assert_eq!(summary.cache_hits, decisions.iter().filter(|d| d.cache_hit).count());
    assert_eq!(summary.median_residual_ms, median);
    assert_eq!(summary.worst_regression_ms, residuals_ms.last().copied());
    if let Some(worst) = summary.worst_regression_ms {
        assert!(worst.is_finite());
    }

    // the report carries the same summary
    assert_eq!(report.telemetry.as_ref(), Some(&summary));
    // and every decision actually changed or re-priced the plan
    for d in decisions {
        assert!((0.0..=1.2 + 1e-9).contains(&d.t_s), "decision at {}", d.t_s);
        assert!(d.decision_s >= 0.0);
    }
}

#[test]
fn telemetry_off_row_is_byte_prefix_of_telemetry_on_row() {
    let (off, _) = run_drift(false);
    let (on, _) = run_drift(true);
    assert!(off.telemetry.is_none());
    assert!(on.telemetry.is_some());
    let (row_off, row_on) = (off.row(), on.row());
    assert!(
        row_on.starts_with(&row_off),
        "telemetry must only append:\n off: {row_off}\n on:  {row_on}"
    );
    assert!(row_on.contains("audit "), "{row_on}");
}

fn run_drift_backend(backend: DpBackend) -> (ServingReport, Engine) {
    let profiler = EnergyProfiler::with_correctors(offline().clone(), || {
        Box::new(EwmaCorrector::default())
    });
    let mut cfg = drift_config(true);
    cfg.dp_backend = backend;
    let mut engine = Engine::with_profiler(cfg, profiler);
    let report = engine.run(&streams()).unwrap();
    (report, engine)
}

/// The DP backend is a pure speed knob: swapping the lattice solver for
/// the map reference must not change one byte of the serving row or one
/// bit of any audited decision (times, fingerprints, predictions, virtual
/// decision cost). Only `solve_wall_s` — measured, jsonl-only — may
/// differ.
#[test]
fn dp_backends_produce_bit_identical_audited_runs() {
    let (rl, el) = run_drift_backend(DpBackend::Lattice);
    let (rm, em) = run_drift_backend(DpBackend::Map);
    assert_eq!(rl.row(), rm.row(), "serving rows diverged across DP backends");
    let (dl, dm) = (
        el.audit().expect("telemetry on").decisions(),
        em.audit().expect("telemetry on").decisions(),
    );
    assert!(!dl.is_empty());
    assert_eq!(dl.len(), dm.len());
    for (a, b) in dl.iter().zip(dm) {
        assert_eq!(a.t_s.to_bits(), b.t_s.to_bits());
        assert_eq!(a.stream, b.stream);
        assert_eq!(a.trigger, b.trigger);
        assert_eq!(a.old_fingerprint, b.old_fingerprint);
        assert_eq!(a.new_fingerprint, b.new_fingerprint);
        assert_eq!(a.cache_hit, b.cache_hit);
        assert_eq!(a.corrector_version, b.corrector_version);
        assert_eq!(a.decision_s.to_bits(), b.decision_s.to_bits());
        assert_eq!(a.pred_after.energy_j.to_bits(), b.pred_after.energy_j.to_bits());
        assert_eq!(a.pred_after.latency_s.to_bits(), b.pred_after.latency_s.to_bits());
        // the measured solve time is the one field allowed to differ —
        // but it must always be present and sane
        assert!(a.solve_wall_s >= 0.0 && a.solve_wall_s.is_finite());
        assert!(b.solve_wall_s >= 0.0 && b.solve_wall_s.is_finite());
    }
}

/// On the fixed-seed drift run, the median measured solve time of true DP
/// solves (cache hits excluded — those never enter either solver core)
/// must not regress under the lattice backend. Wall-clock is host noise,
/// so the run is retried a few times and only the final attempt enforces
/// the (generous) bound — the lattice solver is several times faster, so
/// a genuine regression still fails deterministically.
#[test]
fn lattice_backend_median_solve_time_does_not_regress() {
    fn median_solve_wall_s(engine: &Engine) -> Option<f64> {
        let mut v: Vec<f64> = engine
            .audit()
            .expect("telemetry on")
            .decisions()
            .iter()
            .filter(|d| !d.cache_hit)
            .map(|d| d.solve_wall_s)
            .collect();
        if v.is_empty() {
            return None;
        }
        v.sort_by(f64::total_cmp);
        let n = v.len();
        Some(if n % 2 == 1 {
            v[n / 2]
        } else {
            0.5 * (v[n / 2 - 1] + v[n / 2])
        })
    }
    const ATTEMPTS: usize = 4;
    for attempt in 1..=ATTEMPTS {
        let (_, el) = run_drift_backend(DpBackend::Lattice);
        let (_, em) = run_drift_backend(DpBackend::Map);
        let lat = median_solve_wall_s(&el).expect("drift run recorded no true solves");
        let map = median_solve_wall_s(&em).expect("drift run recorded no true solves");
        if lat <= map {
            return;
        }
        if attempt == ATTEMPTS {
            assert!(
                lat <= map * 1.5,
                "lattice median solve {lat:.3e}s vs map {map:.3e}s after {ATTEMPTS} attempts"
            );
        }
    }
}

fn fleet_cfg(threads: usize) -> FleetRunConfig {
    FleetRunConfig {
        devices: 80,
        threads,
        seed: 42,
        duration_s: 0.8,
        telemetry: true,
        calib: CalibConfig {
            samples: 900,
            seed: 42,
            gbdt: GbdtParams {
                trees: 25,
                ..Default::default()
            },
        },
        ..Default::default()
    }
}

fn fleet_reports() -> &'static (FleetReport, FleetReport) {
    static R: OnceLock<(FleetReport, FleetReport)> = OnceLock::new();
    R.get_or_init(|| {
        let offline = calibrate_classes(&fleet_cfg(1).calib, &DeviceClass::all(), 3);
        (
            run_fleet_with(&fleet_cfg(1), &offline).unwrap(),
            run_fleet_with(&fleet_cfg(8), &offline).unwrap(),
        )
    })
}

#[test]
fn fleet_registry_bit_identical_across_thread_counts() {
    let (a, b) = fleet_reports();
    let ra = a.telemetry.as_ref().expect("telemetry on ⇒ registry present");
    let rb = b.telemetry.as_ref().expect("telemetry on ⇒ registry present");
    // rendered listing is byte-identical …
    assert_eq!(ra.render(), rb.render());
    // … and so is the merged state, down to float bits
    for key in ["sim.offered", "sim.completed", "sim.shed", "sim.op_dispatches"] {
        assert_eq!(ra.counter(key), rb.counter(key), "{key}");
    }
    assert_eq!(
        ra.gauge("fleet.energy_j").unwrap().to_bits(),
        rb.gauge("fleet.energy_j").unwrap().to_bits()
    );
    assert_eq!(
        ra.histogram("latency_s").unwrap().counts(),
        rb.histogram("latency_s").unwrap().counts()
    );
    // the registry tallies agree with the fleet aggregate it rode along
    assert_eq!(ra.counter("sim.completed"), a.fleet.completed as u64);
    assert!(ra.counter("sim.offered") >= ra.counter("sim.completed"));
}
